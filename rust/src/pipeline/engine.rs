//! The GNNDrive pipeline engine (paper §4.1, Fig 4).
//!
//! Four stages — sample, extract, train, release — run as concurrent thread
//! pools connected by three bounded, ID-only queues (extracting, training,
//! releasing). Samplers claim mini-batches from the epoch plan; extractors
//! perform asynchronous two-phase feature extraction into the shared
//! feature buffer; one trainer consumes node-alias lists; one releaser
//! drops references so slots re-enter the standby list. Completion order is
//! naturally out-of-order (mini-batch reordering, §4.3) and backpressure is
//! exactly the paper's: a full queue blocks its producers.

use crate::config::{Machine, OnIoError, TrainConfig};
use crate::extract::{
    CoalesceConfig, CoalesceGovernor, DeviceIoObservation, ExtractError, ExtractOptions,
    ExtractTarget, Extractor, HedgeConfig,
};
use crate::graph::Dataset;
use crate::layout::PackedLayout;
use crate::membuf::{FeatureBuffer, StagingBuffer};
use crate::metrics::state::{self, Role, State};
use crate::sample::PaddedSubgraph;
use crate::sim::queue::BoundedQueue;
use crate::sim::Stopwatch;
use crate::storage::{EpochIoSnapshot, IoBackend as _};
use crate::tier::{TierKind, TierPolicy, TierSnapshot, TieredFeatureStore};
use crate::train::{TrainStats, TrainStep};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// GPU- or CPU-based training variant (§4.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Gpu,
    Cpu,
}

/// Derive padded node caps per level from the memory budget: the feature
/// buffer must hold `(train queue + extractors + 1)` batches, so the cap on
/// nodes per batch follows from the buffer-home capacity — exactly the
/// paper's "the training queue's depth is restricted by the capacity of
/// device memory" (§4.2). Intermediate caps interpolate geometrically and
/// never exceed the no-dedup worst case.
pub fn derive_caps(
    batch: usize,
    fanouts: &[usize],
    dim: usize,
    budget_bytes: u64,
    groups: usize,
    mult: usize,
) -> Vec<usize> {
    let row = (dim * 4) as u64;
    let rows_budget = (budget_bytes / row) as usize;
    let cap_l = (rows_budget / (groups.max(1) * mult.max(1))).max(batch + 1);
    let levels = fanouts.len();
    // No-dedup worst case per level.
    let mut worst = vec![batch];
    for (i, &f) in fanouts.iter().enumerate() {
        worst.push(worst[i] + worst[i] * f);
    }
    let ratio = (cap_l as f64 / batch as f64).max(1.0);
    let mut caps = Vec::with_capacity(levels + 1);
    for i in 0..=levels {
        let geo = (batch as f64 * ratio.powf(i as f64 / levels.max(1) as f64)).round() as usize;
        caps.push(geo.min(worst[i]).max(batch));
    }
    // Monotone non-decreasing.
    for i in 1..caps.len() {
        caps[i] = caps[i].max(caps[i - 1]);
    }
    caps
}

/// Per-epoch outcome of a training system (shared with the baselines).
#[derive(Clone, Debug, Default)]
pub struct EpochStats {
    pub epoch_time: Duration,
    /// Per-epoch preparation time on the critical path (MariusGNN's data
    /// preparation; zero for GNNDrive/PyG+; Ginex's superbatch inspect).
    pub prep_time: Duration,
    /// Sum of per-thread stage busy time.
    pub sample_time: Duration,
    pub extract_time: Duration,
    pub train_time: Duration,
    pub batches: usize,
    pub train: TrainStats,
    /// Out-of-order completions observed by the trainer (inversion count).
    pub reorder_inversions: usize,
    pub ssd_read_bytes: u64,
    /// Charged device read requests this epoch. With segment coalescing one
    /// request covers a whole merged run of feature rows, so this dropping
    /// while `ssd_read_bytes` holds (roughly) steady is the coalescing win.
    pub ssd_read_requests: u64,
    /// Per-mini-batch extraction latency (the tail the serving frontend
    /// competes with): one sample per extracted batch, mergeable across
    /// epochs. Filled by the GNNDrive engine; baselines leave it empty.
    pub extract_hist: crate::util::stats::LatencyHist,
    /// Direct-I/O alignment overhead this epoch: aligned − useful bytes
    /// (§4.4 access-granularity amplification; shrinks when coalescing
    /// dedups shared sectors, grows when gap bridging buys ops with bytes).
    pub align_overhead_bytes: u64,
    pub truncated_edges: usize,
    /// Requests re-issued by the engine retry policy this epoch.
    pub io_retries: u64,
    /// Requests that completed with an error after the policy gave up.
    pub io_failures: u64,
    /// Direct reads served by the `O_DIRECT`→cached bounce-buffer fallback
    /// (OS backend on filesystems that refuse the flag).
    pub direct_fallbacks: u64,
    /// Feature rows trained as zeroed placeholders under
    /// `--on-io-error drop-rows`.
    pub dropped_rows: usize,
    /// Per-device `(reads, read_bytes)` this epoch on a striped array
    /// (single entry — or empty for legacy backends — when unstriped).
    pub device_reads: Vec<(u64, u64)>,
    /// Per-device submission-queue high-water marks, max across this
    /// engine's extractors (cumulative since engine creation — a queue
    /// near `io_depth_per_device` was the epoch's bottleneck device).
    pub queue_highwater: Vec<u64>,
    /// The per-device `--io-depth` budget the high-water marks compare to.
    pub io_depth_per_device: usize,
    /// Per-device `(iops_headroom, bw_headroom)` fractions observed this
    /// epoch — the adaptive-coalescing governor's inputs, surfaced so a log
    /// reader can see *why* the effective config moved.
    pub device_headroom: Vec<(f64, f64)>,
    /// Hedged reissues of straggler segments this epoch (`--hedge`).
    pub io_hedges: u64,
    /// Hedges whose duplicate completed before the stalled original.
    pub hedge_wins: u64,
    /// Batches served from the packed layout this epoch (`train --packed`;
    /// zero on unpacked runs — the log line stays byte-identical).
    pub packed_batches: usize,
    /// Hot-tier rows that were already buffer-resident when their packed
    /// batch began (the pin's payoff).
    pub hot_hits: u64,
    /// Per-epoch GPU-tier counters (`--tier gpu`; `None` on the host-only
    /// path, whose log line stays byte-identical).
    pub tier: Option<TierSnapshot>,
    /// `READ_FIXED` registration failures that silently downgraded the
    /// uring engine to plain `READ` this epoch (`RLIMIT_MEMLOCK`).
    pub fixed_fallbacks: u64,
}

impl EpochStats {
    pub fn summary(&self) -> String {
        let mut s = format!(
            "epoch {:>8}  prep {:>8}  sample {:>8}  extract {:>8}  train {:>8}  batches {:>4}  ssd_read {:>9}  reqs {:>7}  align+ {:>9}  x99 {:>8}  retry {:>4}  iofail {:>3}  fallbk {:>4}  drop {:>4}  loss {:.4}  acc {:.3}",
            crate::util::units::fmt_dur(self.epoch_time),
            crate::util::units::fmt_dur(self.prep_time),
            crate::util::units::fmt_dur(self.sample_time),
            crate::util::units::fmt_dur(self.extract_time),
            crate::util::units::fmt_dur(self.train_time),
            self.batches,
            crate::util::units::fmt_bytes(self.ssd_read_bytes),
            self.ssd_read_requests,
            crate::util::units::fmt_bytes(self.align_overhead_bytes),
            // p99 per-batch extract latency — the tail the serving
            // frontend competes with (zero for baselines, which don't
            // track the histogram).
            crate::util::units::fmt_dur(self.extract_hist.p99()),
            self.io_retries,
            self.io_failures,
            self.direct_fallbacks,
            self.dropped_rows,
            self.train.mean_loss(),
            self.train.accuracy(),
        );
        // Striped arrays only: per-device read split + queue utilization
        // (the `--devices 1` log line stays byte-identical to pre-striping).
        if self.device_reads.len() > 1 {
            let devs: Vec<String> = self
                .device_reads
                .iter()
                .map(|(r, b)| format!("{}r/{}", r, crate::util::units::fmt_bytes(*b)))
                .collect();
            s.push_str(&format!("  dev[{}]", devs.join(" ")));
            if !self.queue_highwater.is_empty() {
                let q: Vec<String> =
                    self.queue_highwater.iter().map(|h| h.to_string()).collect();
                s.push_str(&format!("  q[{}]/{}", q.join(","), self.io_depth_per_device));
            }
            if !self.device_headroom.is_empty() {
                let hr: Vec<String> = self
                    .device_headroom
                    .iter()
                    .map(|(io, bw)| format!("{:.0}/{:.0}", io * 100.0, bw * 100.0))
                    .collect();
                s.push_str(&format!("  hr%[{}]", hr.join(" ")));
            }
        }
        // Hedging runs only (the default no-hedge log line stays identical).
        if self.io_hedges > 0 {
            s.push_str(&format!("  hedge {}w/{}", self.hedge_wins, self.io_hedges));
        }
        // Packed-layout runs only (the unpacked log line stays byte-identical).
        if self.packed_batches > 0 {
            s.push_str(&format!(
                "  packed {}/{}  hot_hits {}",
                self.packed_batches, self.batches, self.hot_hits
            ));
        }
        // GPU-tier runs only (`--tier host` log line stays byte-identical).
        if let Some(t) = &self.tier {
            s.push_str(&format!(
                "  tier gpu {}h/{}h  promo {}  demo {}  byp {}  saved {}",
                t.gpu_hits,
                t.host_hits,
                t.promotions,
                t.demotions,
                t.bypassed,
                crate::util::units::fmt_bytes(t.pcie_saved_bytes),
            ));
            if t.oversub_faults > 0 {
                s.push_str(&format!("  ovsub_faults {}", t.oversub_faults));
            }
        }
        // Registered-buffer degradation (uring backend past RLIMIT_MEMLOCK).
        if self.fixed_fallbacks > 0 {
            s.push_str(&format!("  fixed_fallbk {}", self.fixed_fallbacks));
        }
        s
    }
}

/// One extracted batch in flight between the extractors, the trainer, and
/// the releaser. The alias list rides the whole way: the trainer gathers by
/// it, and the releaser drops references by it (`release_aliases`), so the
/// release path never touches the node→slot map or its shard locks.
struct TrainItem {
    padded: Arc<PaddedSubgraph>,
    aliases: Vec<i32>,
}

/// The GNNDrive engine bound to one machine + dataset + trainer.
///
/// Holds its machine and dataset via `Arc` (not borrows), so built engines
/// are `'static` and can be driven from spawned threads — `build_system`
/// returns `Box<dyn TrainingSystem>` with no leaked lifetime.
pub struct GnnDrive {
    machine: Arc<Machine>,
    ds: Arc<Dataset>,
    cfg: TrainConfig,
    variant: Variant,
    /// Which GPU's memory holds the feature buffer (Fig 13 workers).
    #[allow(dead_code)]
    device_idx: usize,
    fb: Arc<FeatureBuffer>,
    /// Tiered placement facade over `fb` (`--tier`). In host mode a pure
    /// delegate — gathers/releases through it are identical to the buffer's
    /// own — so every call site routes through the store unconditionally.
    store: Arc<TieredFeatureStore>,
    extractors: Vec<Mutex<Extractor>>,
    trainer: Mutex<Box<dyn TrainStep>>,
    caps: Vec<usize>,
    /// Adaptive coalescing governor: retunes the effective per-device
    /// `CoalesceConfig` once per epoch from charged-rate headroom and queue
    /// pressure. Pinned (inert) when the CLI passed explicit coalesce values.
    governor: Mutex<CoalesceGovernor>,
}

impl GnnDrive {
    /// Build the engine: size and reserve the feature buffer
    /// ((queue+extractors+1) × cap_L slots), one staging buffer + async
    /// I/O engine per extractor. Fails with OOM if the budgets cannot fit
    /// (which is a *result* for the memory-sweep experiments, not a crash).
    pub fn new(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: TrainConfig,
        variant: Variant,
        trainer: Box<dyn TrainStep>,
    ) -> anyhow::Result<Self> {
        Self::new_on_device(machine, ds, cfg, variant, 0, trainer)
    }

    /// Multi-GPU data parallelism (Fig 13): each worker's pipeline owns one
    /// GPU's feature buffer.
    pub fn new_on_device(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: TrainConfig,
        variant: Variant,
        device_idx: usize,
        trainer: Box<dyn TrainStep>,
    ) -> anyhow::Result<Self> {
        let caps = trainer.caps().to_vec();
        assert_eq!(trainer.dim(), ds.spec.dim, "trainer/dataset dim mismatch");
        let cap_l = *caps.last().unwrap();
        let mut groups = cfg.train_queue_cap + cfg.extractors + 1;
        if cfg.enforce_order {
            // In-order training can hold up to `extractors` additional
            // batches in the trainer's reorder hold-back buffer.
            groups += cfg.extractors;
        }
        let slots = groups * cap_l * cfg.feature_buffer_mult.max(1);
        let fb = match variant {
            Variant::Gpu => FeatureBuffer::in_device(&machine.devices[device_idx], slots, ds.spec.dim)
                .map_err(anyhow::Error::new)?,
            Variant::Cpu => FeatureBuffer::in_host(&machine.host, slots, ds.spec.dim)
                .map_err(anyhow::Error::new)?,
        };
        let fb = Arc::new(fb);
        // Tiered placement (`--tier gpu`): the hot tier's arena is reserved
        // against the same GPU's memory as the feature buffer, sized by
        // `--gpu-mem`, with the graph's degree array as the promotion prior.
        let store = match cfg.tier {
            TierKind::Host => TieredFeatureStore::host(fb.clone()),
            TierKind::Gpu => TieredFeatureStore::gpu(
                fb.clone(),
                &machine.devices[device_idx],
                machine.pcie.clone(),
                cfg.gpu_mem,
                TierPolicy {
                    oversub: cfg.gpu_oversub,
                    indptr: Some(ds.graph.indptr.clone()),
                    ..TierPolicy::default()
                },
            )
            .map_err(anyhow::Error::new)?,
        };
        let row_bytes = ds.features.row_bytes() as usize;
        // The staging buffer "can be expanded or shrunk … with regard to the
        // volume of topological data and the capacity of available host
        // memory" (§4.2): start at cap_L (capped) and halve until the
        // reservation fits, down to a 256-row floor. Extraction then simply
        // proceeds in more waves.
        let mut staging_slots = cap_l.min(4096);
        let coalesce =
            CoalesceConfig { max_bytes: cfg.coalesce_bytes, gap_bytes: cfg.coalesce_gap };
        let governor = Mutex::new(CoalesceGovernor::new(
            coalesce,
            machine.backend.stripe().devices,
            cfg.coalesce_pinned,
        ));
        let mut extractors = Vec::with_capacity(cfg.extractors);
        for _ in 0..cfg.extractors {
            let staging = loop {
                match StagingBuffer::new(&machine.host, staging_slots, row_bytes) {
                    Ok(s) => break s,
                    Err(_) if staging_slots > 256 => staging_slots /= 2,
                    Err(e) => return Err(anyhow::Error::new(e)),
                }
            };
            let target = match variant {
                Variant::Gpu => ExtractTarget::Device(machine.pcie.clone()),
                Variant::Cpu => ExtractTarget::Host,
            };
            let mut extractor = Extractor::with_options(
                machine.backend.clone(),
                cfg.io_depth,
                staging,
                fb.clone(),
                ds.features.clone(),
                target,
                ExtractOptions {
                    asynchronous: !cfg.sync_extract,
                    direct: !cfg.buffered_features,
                    coalesce,
                    hedge: HedgeConfig { enabled: cfg.hedge, pin_us: cfg.hedge_us },
                },
            );
            if store.is_gpu() {
                extractor.set_tier(store.clone());
            }
            extractors.push(Mutex::new(extractor));
        }
        Ok(GnnDrive {
            machine: machine.clone(),
            ds: ds.clone(),
            cfg,
            variant,
            device_idx,
            fb,
            store,
            extractors,
            trainer: Mutex::new(trainer),
            caps,
            governor,
        })
    }

    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    pub fn feature_buffer(&self) -> &Arc<FeatureBuffer> {
        &self.fb
    }

    /// The tiered placement store (a pure delegate in `--tier host` runs).
    pub fn tiered_store(&self) -> &Arc<TieredFeatureStore> {
        &self.store
    }

    pub fn variant(&self) -> Variant {
        self.variant
    }

    /// Attach a packed layout (`train --packed`): verifies the schedule
    /// handshake, pins as many hot-tier rows as the feature buffer can spare
    /// beyond the pipeline's working floor, and hands the layout to every
    /// extractor so covered batches extract from their sequential pack runs.
    /// Returns the number of hot rows pinned.
    pub fn attach_layout(&mut self, layout: Arc<PackedLayout>) -> anyhow::Result<usize> {
        layout.verify_schedule(&self.cfg.schedule_spec())?;
        anyhow::ensure!(
            self.cfg.segment.is_none(),
            "packed layout was pre-sampled over the full train split; \
             it cannot serve a segmented (multi-worker) plan"
        );
        // Pin budget: slots beyond what the pipeline needs to keep
        // `groups` batches in flight at the padded cap. With the default
        // --feature-buffer-mult 1 this is ~0 (no pin — hot rows still read
        // sequentially from hot.bin); raise the mult to buy pin headroom.
        let cap_l = *self.caps.last().unwrap();
        let mut groups = self.cfg.train_queue_cap + self.cfg.extractors + 1;
        if self.cfg.enforce_order {
            groups += self.cfg.extractors;
        }
        let floor = groups * cap_l;
        let budget = self.fb.n_slots.saturating_sub(floor);
        // Tiered runs pin the hottest rows into the GPU tier first; the
        // remainder (and the whole hot set in host mode) overflows to the
        // host buffer's pin budget.
        let gpu_pinned =
            crate::layout::pin_hot_gpu(&self.store, &layout, self.machine.backend.as_ref());
        let pinned = crate::layout::pin_hot_from(
            &self.fb,
            &layout,
            self.machine.backend.as_ref(),
            budget,
            gpu_pinned,
        );
        for ex in &self.extractors {
            ex.lock().unwrap_or_else(|e| e.into_inner()).set_layout(layout.clone());
        }
        Ok(gpu_pinned + pinned)
    }

    /// Sum of `(packed_batches, hot_hits)` across this engine's extractors.
    fn packed_totals(&self) -> (u64, u64) {
        let mut t = (0u64, 0u64);
        for ex in &self.extractors {
            let (p, h) = ex.lock().unwrap_or_else(|e| e.into_inner()).packed_stats();
            t.0 += p;
            t.1 += h;
        }
        t
    }

    /// This engine's share of the train split (strided segment, §4.3).
    fn segment_ids(&self) -> Vec<u32> {
        match self.cfg.segment {
            Some((w, n)) if n > 1 => self
                .ds
                .train_ids
                .iter()
                .copied()
                .skip(w)
                .step_by(n)
                .collect(),
            _ => self.ds.train_ids.clone(),
        }
    }

    /// Run one full SET epoch; returns per-stage stats. Infallible facade
    /// over [`GnnDrive::try_run_epoch`] — panics if the epoch aborts on an
    /// I/O error under `--on-io-error fail` (tests and legacy callers that
    /// never inject faults keep the simple signature).
    pub fn run_epoch(&self, epoch: u64) -> EpochStats {
        self.try_run_epoch(epoch)
            .unwrap_or_else(|e| panic!("epoch {epoch} aborted: {e}"))
    }

    /// Run one full SET epoch, surfacing unrecoverable I/O errors as a typed
    /// `Err` instead of a panic or a hang.
    ///
    /// The per-batch policy is `cfg.on_io_error`:
    /// * `Fail` — first degraded batch aborts the epoch: the error is
    ///   recorded, both queues close so every stage drains and joins, and
    ///   the typed error is returned.
    /// * `Retry` — the degraded batch's rows are released, the failed rows'
    ///   zeroed placeholders are evicted (so the retry re-reads the backing
    ///   store instead of aliasing stale zeros), and the batch is extracted
    ///   once more; a second failure escalates to `Fail` semantics.
    /// * `DropRows` — the batch trains with the failed rows zeroed; the row
    ///   count lands in [`EpochStats::dropped_rows`].
    pub fn try_run_epoch(&self, epoch: u64) -> anyhow::Result<EpochStats> {
        let clock = &self.machine.clock;
        let ids = self.segment_ids();
        // One ScheduleSpec derives both the plan and the samplers, so this
        // epoch replays bit-identically to the offline pre-sampler's
        // (`layout::pack_dataset`) — the packed-extraction correctness hinge.
        let schedule = self.cfg.schedule_spec();
        let plan = schedule.plan(&ids, epoch);
        let total_batches = plan.len();
        let extract_q = BoundedQueue::<Arc<PaddedSubgraph>>::new(self.cfg.extract_queue_cap);
        let train_q = BoundedQueue::<TrainItem>::new(self.cfg.train_queue_cap);
        let release_q = BoundedQueue::<TrainItem>::new(64);

        let sample_ns = AtomicU64::new(0);
        let extract_ns = AtomicU64::new(0);
        let extract_hist = Mutex::new(crate::util::stats::LatencyHist::default());
        let train_ns = AtomicU64::new(0);
        let samplers_left = AtomicUsize::new(self.cfg.samplers);
        let extractors_left = AtomicUsize::new(self.cfg.extractors);
        let train_stats = Mutex::new(TrainStats::default());
        let train_order = Mutex::new(Vec::<u64>::with_capacity(total_batches));
        let truncated = AtomicUsize::new(0);
        let dropped = AtomicUsize::new(0);
        // First unrecoverable extraction error (under `fail`, or `retry`
        // exhausted). Setting it closes both queues, so every stage drains
        // and the scope joins — the epoch *terminates* with a typed error.
        let epoch_err = Mutex::new(None::<ExtractError>);

        let epoch_watch = Stopwatch::start(clock);
        let io_snap = EpochIoSnapshot::start(self.machine.backend.as_ref());
        let dev_snap = self.machine.backend.device_io_snapshot();
        // Extractor packed counters are cumulative; take per-epoch deltas.
        let packed0 = self.packed_totals();
        // Tier counters likewise (all-zero snapshot in host mode).
        let tier0 = self.store.snapshot();

        std::thread::scope(|s| {
            // ---- samplers ----
            for t in 0..self.cfg.samplers {
                let plan = &plan;
                let extract_q = &extract_q;
                let sample_ns = &sample_ns;
                let samplers_left = &samplers_left;
                let truncated = &truncated;
                let sampler = schedule.sampler(epoch);
                s.spawn(move || {
                    state::register(Role::Sampler);
                    let _ = t;
                    while let Some((batch_id, seeds)) = plan.claim() {
                        let sw = Stopwatch::start(clock);
                        let sub = sampler.sample_batch(
                            &self.ds,
                            self.machine.backend.as_ref(),
                            batch_id,
                            seeds,
                        );
                        let padded = sub.pad(&self.caps, &self.cfg.fanouts);
                        truncated.fetch_add(padded.truncated_edges, Ordering::Relaxed);
                        sample_ns
                            .fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        let _idle = state::enter(State::Idle);
                        if extract_q.push(Arc::new(padded)).is_err() {
                            break;
                        }
                    }
                    if samplers_left.fetch_sub(1, Ordering::SeqCst) == 1 {
                        extract_q.close();
                    }
                    state::deregister();
                });
            }

            // ---- extractors ----
            for ex in self.extractors.iter() {
                let extract_q = &extract_q;
                let train_q = &train_q;
                let extract_ns = &extract_ns;
                let extract_hist = &extract_hist;
                let extractors_left = &extractors_left;
                let dropped = &dropped;
                let epoch_err = &epoch_err;
                let fb = &self.store;
                let on_io_error = self.cfg.on_io_error;
                s.spawn(move || {
                    state::register(Role::Extractor);
                    let ex = ex.lock().unwrap_or_else(|e| e.into_inner());
                    loop {
                        let padded = {
                            let _idle = state::enter(State::Idle);
                            match extract_q.pop() {
                                Ok(p) => p,
                                Err(_) => break,
                            }
                        };
                        let sw = Stopwatch::start(clock);
                        let nodes = &padded.nodes[..padded.real_nodes];
                        let ctx = Some((epoch, padded.batch_id));
                        let mut result = ex.try_extract_at(nodes, ctx);
                        if let (Err(e), OnIoError::Retry) = (&result, on_io_error) {
                            // One bounded re-extract: drop the degraded
                            // batch's refs, evict the failed rows' zeroed
                            // placeholders (else the retry would alias
                            // them as cached hits), read again.
                            fb.release_aliases(&e.aliases);
                            fb.evict_if_idle(&e.failed_nodes);
                            result = ex.try_extract_at(nodes, ctx);
                        }
                        let aliases = match result {
                            Ok(a) => a,
                            Err(e) if on_io_error == OnIoError::DropRows => {
                                dropped.fetch_add(e.failed_nodes.len(), Ordering::Relaxed);
                                e.aliases
                            }
                            Err(e) => {
                                // `fail`, or `retry` exhausted: abort the
                                // epoch. Refs are dropped here because
                                // this item never reaches the releaser.
                                fb.release_aliases(&e.aliases);
                                let mut slot =
                                    epoch_err.lock().unwrap_or_else(|p| p.into_inner());
                                slot.get_or_insert(e);
                                drop(slot);
                                extract_q.close();
                                train_q.close();
                                break;
                            }
                        };
                        let took = sw.elapsed();
                        extract_ns.fetch_add(took.as_nanos() as u64, Ordering::Relaxed);
                        extract_hist.lock().unwrap().record(took);
                        let _idle = state::enter(State::Idle);
                        // The push consumes the item even on a closed
                        // queue, so keep the alias list recoverable: a
                        // batch that never reaches the releaser (peer
                        // aborted the epoch) must drop its refs here.
                        let recover = aliases.clone();
                        if train_q.push(TrainItem { padded, aliases }).is_err() {
                            fb.release_aliases(&recover);
                            break;
                        }
                    }
                    if extractors_left.fetch_sub(1, Ordering::SeqCst) == 1 {
                        train_q.close();
                    }
                    state::deregister();
                });
            }

            // ---- trainer ----
            {
                let train_q = &train_q;
                let release_q = &release_q;
                let train_ns = &train_ns;
                let train_stats = &train_stats;
                let train_order = &train_order;
                let fb = &self.store;
                s.spawn(move || {
                    state::register(Role::Trainer);
                    let mut trainer = self.trainer.lock().unwrap();
                    let dim = trainer.dim();
                    let cap_l = *trainer.caps().last().unwrap();
                    let mut feats = vec![0f32; cap_l * dim];
                    // Ablation (`enforce_order`): hold out-of-order batches
                    // until the expected id arrives — the paper's reordering
                    // disabled.
                    let mut pending: std::collections::BTreeMap<u64, TrainItem> =
                        std::collections::BTreeMap::new();
                    let mut next_id: u64 = 0;
                    loop {
                        let item = if self.cfg.enforce_order {
                            if let Some(item) = pending.remove(&next_id) {
                                item
                            } else {
                                let _idle = state::enter(State::Idle);
                                match train_q.pop() {
                                    Ok(i) if i.padded.batch_id == next_id => i,
                                    Ok(i) => {
                                        pending.insert(i.padded.batch_id, i);
                                        continue;
                                    }
                                    Err(_) => match pending.pop_first() {
                                        Some((_, i)) => i,
                                        None => break,
                                    },
                                }
                            }
                        } else {
                            let _idle = state::enter(State::Idle);
                            match train_q.pop() {
                                Ok(i) => i,
                                Err(_) => break,
                            }
                        };
                        next_id = item.padded.batch_id + 1;
                        let sw = Stopwatch::start(clock);
                        if trainer.is_real() {
                            // Index the device feature buffer by node alias.
                            let _busy = state::enter(State::Busy);
                            fb.gather(&item.aliases, &mut feats[..item.aliases.len() * dim]);
                            feats[item.aliases.len() * dim..].fill(0.0);
                        }
                        let r = trainer.step(&item.padded, &feats);
                        train_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        train_stats.lock().unwrap().push(&r);
                        train_order.lock().unwrap().push(item.padded.batch_id);
                        let _idle = state::enter(State::Idle);
                        if release_q.push(item).is_err() {
                            break;
                        }
                    }
                    release_q.close();
                    state::deregister();
                });
            }

            // ---- releaser ----
            {
                let release_q = &release_q;
                let fb = &self.store;
                s.spawn(move || {
                    state::register(Role::Releaser);
                    loop {
                        let item = {
                            let _idle = state::enter(State::Idle);
                            match release_q.pop() {
                                Ok(i) => i,
                                Err(_) => break,
                            }
                        };
                        // Release by alias (the plan's slot indexes): one
                        // atomic decrement per row — no map lookup, no
                        // shard lock, no contention with planning peers.
                        fb.release_aliases(&item.aliases);
                    }
                    state::deregister();
                });
            }
        });

        if let Some(e) = epoch_err.into_inner().unwrap_or_else(|p| p.into_inner()) {
            return Err(anyhow::Error::new(e).context(format!(
                "epoch {epoch} aborted by I/O error (policy: {:?})",
                self.cfg.on_io_error
            )));
        }
        let order = train_order.into_inner().unwrap();
        let io = io_snap.totals(self.machine.backend.as_ref());
        // Per-device read split this epoch (end − start, zipped by device;
        // a legacy backend's single-entry snapshot works unchanged).
        let device_reads: Vec<(u64, u64)> = self
            .machine
            .backend
            .device_io_snapshot()
            .iter()
            .enumerate()
            .map(|(d, &(reads, bytes))| {
                let (r0, b0) = dev_snap.get(d).copied().unwrap_or((0, 0));
                (reads.saturating_sub(r0), bytes.saturating_sub(b0))
            })
            .collect();
        // Submission-queue high-water per device: max across this engine's
        // extractors (each owns its async engine). Extractor threads joined
        // at scope exit, so the locks are uncontended here.
        let mut queue_highwater: Vec<u64> = Vec::new();
        for ex in &self.extractors {
            let hw = ex.lock().unwrap_or_else(|e| e.into_inner()).queue_highwater();
            for (d, &v) in hw.iter().enumerate() {
                if d < queue_highwater.len() {
                    queue_highwater[d] = queue_highwater[d].max(v);
                } else {
                    queue_highwater.push(v);
                }
            }
        }
        let packed1 = self.packed_totals();
        // Converge tier housekeeping (queued demotions, deferred host
        // evictions) off the epoch's critical path before snapshotting —
        // a no-op in host mode.
        self.store.quiesce();
        let tier = if self.store.is_gpu() {
            Some(self.store.snapshot().since(&tier0))
        } else {
            None
        };
        let epoch_time = epoch_watch.elapsed();
        // Close the adaptive-coalescing feedback loop (ISSUE 9): fold this
        // epoch's per-device charge rates into the governor, then push the
        // retuned effective configs into every extractor so the *next*
        // epoch plans with them. The device model's ceilings come from the
        // machine's SSD config (nominal for the OS backends — from_charges
        // reports full headroom when a ceiling is unknown/zero).
        let ssd = &self.machine.cfg.ssd;
        let secs = epoch_time.as_secs_f64();
        let obs: Vec<DeviceIoObservation> = device_reads
            .iter()
            .enumerate()
            .map(|(d, &(reads, bytes))| {
                DeviceIoObservation::from_charges(
                    reads,
                    bytes,
                    secs,
                    ssd.iops,
                    ssd.read_bw,
                    queue_highwater.get(d).copied().unwrap_or(0),
                    self.cfg.io_depth,
                )
            })
            .collect();
        let device_headroom: Vec<(f64, f64)> =
            obs.iter().map(|o| (o.iops_headroom, o.bw_headroom)).collect();
        {
            let mut gov = self.governor.lock().unwrap_or_else(|e| e.into_inner());
            gov.observe_epoch(&obs);
            if !gov.pinned() {
                for ex in &self.extractors {
                    ex.lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .set_coalesce_configs(gov.configs());
                }
            }
        }
        Ok(EpochStats {
            epoch_time,
            prep_time: Duration::ZERO,
            sample_time: Duration::from_nanos(sample_ns.into_inner()),
            extract_time: Duration::from_nanos(extract_ns.into_inner()),
            train_time: Duration::from_nanos(train_ns.into_inner()),
            batches: order.len(),
            train: train_stats.into_inner().unwrap(),
            reorder_inversions: count_inversions(&order),
            ssd_read_bytes: io.read_bytes,
            ssd_read_requests: io.reads,
            extract_hist: extract_hist.into_inner().unwrap(),
            align_overhead_bytes: io.align_overhead_bytes,
            truncated_edges: truncated.into_inner(),
            io_retries: io.io_retries,
            io_failures: io.io_failures,
            direct_fallbacks: io.direct_fallbacks,
            dropped_rows: dropped.into_inner(),
            device_reads,
            queue_highwater,
            io_depth_per_device: self.cfg.io_depth,
            device_headroom,
            io_hedges: io.io_hedges,
            hedge_wins: io.hedge_wins,
            packed_batches: (packed1.0 - packed0.0) as usize,
            hot_hits: packed1.1 - packed0.1,
            tier,
            fixed_fallbacks: io.fixed_fallbacks,
        })
    }

    /// Sample-only epoch (Fig 2's `-only` condition): run the samplers over
    /// the full plan with no extraction; returns the summed sampling time.
    pub fn run_sample_only(&self, epoch: u64) -> Duration {
        let clock = &self.machine.clock;
        let ids = self.segment_ids();
        let schedule = self.cfg.schedule_spec();
        let plan = schedule.plan(&ids, epoch);
        let sample_ns = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.cfg.samplers {
                let plan = &plan;
                let sample_ns = &sample_ns;
                let sampler = schedule.sampler(epoch);
                s.spawn(move || {
                    state::register(Role::Sampler);
                    while let Some((batch_id, seeds)) = plan.claim() {
                        let sw = Stopwatch::start(clock);
                        let sub = sampler.sample_batch(
                            &self.ds,
                            self.machine.backend.as_ref(),
                            batch_id,
                            seeds,
                        );
                        std::hint::black_box(&sub);
                        sample_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    state::deregister();
                });
            }
        });
        Duration::from_nanos(sample_ns.into_inner())
    }
}

/// Inversions in the trainer's observed batch order (0 = fully in-order).
/// Merge-sort count, O(n log n) — with thousands of batches per epoch the
/// old double loop was measurable epoch-stats overhead.
fn count_inversions(order: &[u64]) -> usize {
    fn merge_count(xs: &mut [u64], scratch: &mut Vec<u64>) -> usize {
        let n = xs.len();
        if n < 2 {
            return 0;
        }
        let mid = n / 2;
        let (lo, hi) = xs.split_at_mut(mid);
        let mut inv = merge_count(lo, scratch) + merge_count(hi, scratch);
        scratch.clear();
        let (mut i, mut j) = (0, 0);
        while i < lo.len() && j < hi.len() {
            if lo[i] <= hi[j] {
                scratch.push(lo[i]);
                i += 1;
            } else {
                // hi[j] jumps ahead of every remaining left element.
                inv += lo.len() - i;
                scratch.push(hi[j]);
                j += 1;
            }
        }
        scratch.extend_from_slice(&lo[i..]);
        scratch.extend_from_slice(&hi[j..]);
        xs.copy_from_slice(scratch);
        inv
    }
    let mut xs = order.to_vec();
    let mut scratch = Vec::with_capacity(xs.len());
    merge_count(&mut xs, &mut scratch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GpuModel, MachineConfig};
    use crate::graph::DatasetSpec;
    use crate::runtime::simcompute::{ModelKind, SimTrainStep};
    use crate::sim::Clock;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            batch_size: 64,
            fanouts: vec![4, 4],
            batches_per_epoch: Some(4),
            samplers: 2,
            extractors: 2,
            io_depth: 32,
            ..TrainConfig::default()
        }
    }

    fn build_engine(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: &TrainConfig,
        variant: Variant,
    ) -> GnnDrive {
        let budget = match variant {
            Variant::Gpu => machine.devices[0].capacity() * 9 / 10,
            Variant::Cpu => machine.host.capacity() / 4,
        };
        let groups = cfg.train_queue_cap + cfg.extractors + 1;
        let caps = derive_caps(cfg.batch_size, &cfg.fanouts, ds.spec.dim, budget, groups, 1);
        let trainer = SimTrainStep::new(
            if variant == Variant::Cpu { GpuModel::CpuOnly } else { GpuModel::Rtx3090 },
            machine.clock.clone(),
            ModelKind::GraphSage,
            caps,
            cfg.fanouts.clone(),
            ds.spec.dim,
            64,
            ds.spec.classes,
        );
        GnnDrive::new(machine, ds, cfg.clone(), variant, Box::new(trainer)).unwrap()
    }

    #[test]
    fn caps_derivation_monotone_and_bounded() {
        let caps = derive_caps(1000, &[10, 10, 10], 128, 96 << 20, 9, 1);
        assert_eq!(caps.len(), 4);
        assert_eq!(caps[0], 1000);
        for w in caps.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // cap_L bounded by budget: 96MiB/512B/9 ≈ 21.8k rows.
        assert!(*caps.last().unwrap() <= 22_000);
        // Worst-case bound respected for small fanouts.
        let caps = derive_caps(10, &[2, 2], 16, 1 << 30, 2, 1);
        assert!(caps[1] <= 30);
        assert!(caps[2] <= 90);
    }

    #[test]
    fn gpu_epoch_runs_and_trains_all_batches() {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let cfg = quick_cfg();
        let engine = build_engine(&machine, &ds, &cfg, Variant::Gpu);
        let stats = engine.run_epoch(0);
        assert_eq!(stats.batches, 4);
        assert_eq!(stats.train.steps, 4);
        assert!(stats.epoch_time > Duration::ZERO);
        assert!(stats.extract_time > Duration::ZERO);
        assert_eq!(stats.extract_hist.count(), 4, "one latency sample per batch");
        assert!(stats.extract_hist.p99() >= stats.extract_hist.p50());
        assert!(stats.ssd_read_bytes > 0);
        engine.feature_buffer().check_invariants().unwrap();
        // After release, every slot with zero refs: standby holds them all.
        let (_, _, _, loads) = engine.feature_buffer().stats();
        assert!(loads > 0);
    }

    #[test]
    fn cpu_variant_runs() {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let cfg = quick_cfg();
        let engine = build_engine(&machine, &ds, &cfg, Variant::Cpu);
        let stats = engine.run_epoch(0);
        assert_eq!(stats.batches, 4);
        engine.feature_buffer().check_invariants().unwrap();
    }

    #[test]
    fn sample_only_mode_reports_time() {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let cfg = quick_cfg();
        let engine = build_engine(&machine, &ds, &cfg, Variant::Gpu);
        let t = engine.run_sample_only(0);
        assert!(t > Duration::ZERO);
    }

    #[test]
    fn second_epoch_reuses_buffer_contents() {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let mut cfg = quick_cfg();
        cfg.batches_per_epoch = Some(2);
        let engine = build_engine(&machine, &ds, &cfg, Variant::Gpu);
        engine.run_epoch(0);
        let (hits0, _, _, loads0) = engine.feature_buffer().stats();
        engine.run_epoch(1);
        let (hits1, _, _, loads1) = engine.feature_buffer().stats();
        // Epoch 2 should find some rows still resident (inter-batch
        // locality through the standby list).
        assert!(hits1 > hits0, "no cross-epoch reuse: {hits0}->{hits1}");
        assert!(loads1 > loads0);
        engine.feature_buffer().check_invariants().unwrap();
    }

    #[test]
    fn summary_renders_headroom_and_hedges_only_when_present() {
        let mut st = EpochStats::default();
        assert!(!st.summary().contains("hr%"), "flat summary must stay clean");
        assert!(!st.summary().contains("hedge"), "no-hedge summary must stay clean");
        st.device_reads = vec![(1, 512), (2, 1024)];
        st.queue_highwater = vec![3, 4];
        st.io_depth_per_device = 32;
        st.device_headroom = vec![(0.5, 0.25), (1.0, 0.0)];
        st.io_hedges = 7;
        st.hedge_wins = 2;
        let s = st.summary();
        assert!(s.contains("hr%[50/25 100/0]"), "missing headroom: {s}");
        assert!(s.contains("hedge 2w/7"), "missing hedge counters: {s}");
    }

    #[test]
    fn striped_epoch_reports_headroom_and_feeds_governor() {
        let machine = Arc::new(Machine::new(
            MachineConfig::paper().with_devices(3).with_stripe_bytes(4096),
            Clock::new(0.05),
        ));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let cfg = quick_cfg();
        let engine = build_engine(&machine, &ds, &cfg, Variant::Gpu);
        let stats = engine.run_epoch(0);
        assert_eq!(stats.device_headroom.len(), 3);
        for &(io, bw) in &stats.device_headroom {
            assert!((0.0..=1.0).contains(&io), "iops headroom out of range: {io}");
            assert!((0.0..=1.0).contains(&bw), "bw headroom out of range: {bw}");
        }
        assert!(stats.summary().contains("hr%["));
        assert_eq!(stats.io_hedges, 0, "hedging is opt-in and off by default");
        // The governor is wired per device and unpinned by default.
        let gov = engine.governor.lock().unwrap();
        assert_eq!(gov.configs().len(), 3);
        assert!(!gov.pinned());
    }

    #[test]
    fn pinned_governor_leaves_extractor_overrides_alone() {
        let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let mut cfg = quick_cfg();
        cfg.coalesce_pinned = true;
        let engine = build_engine(&machine, &ds, &cfg, Variant::Gpu);
        let stats = engine.run_epoch(0);
        assert_eq!(stats.batches, 4);
        assert!(engine.governor.lock().unwrap().pinned());
    }

    #[test]
    fn inversion_count() {
        assert_eq!(count_inversions(&[0, 1, 2, 3]), 0);
        assert_eq!(count_inversions(&[1, 0, 2, 3]), 1);
        assert_eq!(count_inversions(&[3, 2, 1, 0]), 6);
        assert_eq!(count_inversions(&[]), 0);
        assert_eq!(count_inversions(&[5]), 0);
        assert_eq!(count_inversions(&[2, 2, 2]), 0, "ties are not inversions");
    }

    #[test]
    fn inversion_count_matches_naive_on_random_orders() {
        fn naive(order: &[u64]) -> usize {
            let mut inv = 0;
            for i in 0..order.len() {
                for j in i + 1..order.len() {
                    if order[i] > order[j] {
                        inv += 1;
                    }
                }
            }
            inv
        }
        let mut rng = crate::util::rng::Pcg::new(42);
        for len in [2usize, 3, 7, 64, 257] {
            for _ in 0..8 {
                let xs: Vec<u64> = (0..len).map(|_| rng.next_u64() % 50).collect();
                assert_eq!(count_inversions(&xs), naive(&xs), "len {len}: {xs:?}");
            }
        }
    }
}
