//! The GNNDrive pipeline: stages, queues, reordering (paper §4.1/§4.3).

pub mod engine;

pub use engine::{derive_caps, EpochStats, GnnDrive, Variant};
