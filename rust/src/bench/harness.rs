//! Minimal measurement harness (no criterion offline): warmup + timed
//! iterations with mean/σ/min reporting, used by the micro-benchmarks.

use crate::util::stats::Online;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl Measurement {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>10}/iter  σ {:>9}  min {:>9}  ({} iters)",
            self.name,
            crate::util::units::fmt_dur(self.mean),
            crate::util::units::fmt_dur(self.std),
            crate::util::units::fmt_dur(self.min),
            self.iters
        )
    }
}

/// Time `f` over `iters` iterations after `warmup` runs; each sample is one
/// iteration (use inner batching in `f` for sub-microsecond work).
pub fn measure<F: FnMut()>(name: &str, warmup: u64, iters: u64, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut stats = Online::default();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        stats.push(t0.elapsed().as_secs_f64());
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean: Duration::from_secs_f64(stats.mean()),
        std: Duration::from_secs_f64(stats.std()),
        min: Duration::from_secs_f64(stats.min()),
    }
}

/// Throughput helper: report ns/op for `ops` operations per call.
pub fn per_op(m: &Measurement, ops: u64) -> Duration {
    Duration::from_secs_f64(m.mean.as_secs_f64() / ops as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_roughly() {
        let m = measure("sleep 2ms", 1, 5, || {
            std::thread::sleep(Duration::from_millis(2))
        });
        assert!(m.mean >= Duration::from_millis(2));
        assert!(m.mean < Duration::from_millis(20));
        assert!(m.row().contains("sleep 2ms"));
        assert!(per_op(&m, 1000) < Duration::from_micros(20));
    }
}
