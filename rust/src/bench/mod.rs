//! In-repo measurement harness (criterion substitute for the offline build).

pub mod harness;

pub use harness::{measure, per_op, Measurement};
