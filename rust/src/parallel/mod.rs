//! Multi-GPU data parallelism (paper §4.3, Fig 7, Fig 13).
//!
//! The training set splits into per-worker *segments*; each worker runs its
//! own full GNNDrive pipeline (samplers, extractors, feature buffer on its
//! GPU, trainer, releaser) against the shared machine substrate (one SSD,
//! one host-memory budget, one PCIe link — contention included). Gradient
//! synchronization in the backward pass is modeled by a loose step barrier
//! plus an all-reduce transfer cost over PCIe: `2·(W−1)/W × param_bytes`.
//! Finished workers leave the barrier group so uneven segments cannot
//! deadlock.

pub mod sync;

use crate::config::{GpuModel, Machine, TrainConfig};
use crate::graph::Dataset;
use crate::pipeline::{EpochStats, GnnDrive, Variant};
use crate::runtime::simcompute::{ModelKind, SimTrainStep};
use crate::sample::PaddedSubgraph;
use crate::train::{StepResult, TrainStep};
use std::sync::Arc;
use std::time::Duration;
use sync::SyncGroup;

/// Wraps a worker's trainer with the gradient-synchronization protocol.
struct SyncedTrainStep {
    inner: Box<dyn TrainStep>,
    group: Arc<SyncGroup>,
    worker: usize,
    allreduce: Duration,
    clock: crate::sim::Clock,
    step_no: u64,
}

impl TrainStep for SyncedTrainStep {
    fn caps(&self) -> &[usize] {
        self.inner.caps()
    }
    fn fanouts(&self) -> &[usize] {
        self.inner.fanouts()
    }
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn step(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        let r = self.inner.step(batch, features);
        // Backward-pass gradient synchronization with the other workers.
        self.group.arrive(self.worker, self.step_no);
        self.step_no += 1;
        let _io = crate::metrics::state::enter(crate::metrics::state::State::Io);
        self.clock.sleep(self.allreduce);
        r
    }

    fn forward(&mut self, batch: &PaddedSubgraph, features: &[f32]) -> StepResult {
        // Read-only inference: no parameter update, so no barrier arrival
        // and no all-reduce — delegating to the default (a full synced
        // step) would mutate parameters and block on peers that are not
        // stepping.
        self.inner.forward(batch, features)
    }

    fn is_real(&self) -> bool {
        self.inner.is_real()
    }
}

/// One row of the Fig 13 series.
#[derive(Clone, Debug)]
pub struct ScalingPoint {
    pub workers: usize,
    pub epoch_time: Duration,
    pub batches: usize,
}

/// Estimate of parameter bytes for the all-reduce (paper models: 3 layers,
/// hidden 256).
fn param_bytes(dim: usize, hidden: usize, classes: usize, levels: usize) -> usize {
    let mut total = 0;
    for step in 0..levels {
        let d_in = if step == 0 { dim } else { hidden };
        let d_out = if step == levels - 1 { classes } else { hidden };
        total += (2 * d_in * d_out + d_out) * 4;
    }
    total
}

/// Run one epoch with `workers` data-parallel pipelines; returns the wall
/// epoch time (slowest worker) and total batches.
pub fn run_parallel_epoch(
    machine: &Arc<Machine>,
    ds: &Arc<Dataset>,
    base_cfg: &TrainConfig,
    model: ModelKind,
    variant: Variant,
    workers: usize,
    epoch: u64,
) -> anyhow::Result<ScalingPoint> {
    assert!(workers >= 1);
    let workers = workers.min(machine.devices.len().max(1));
    let group = Arc::new(SyncGroup::new(workers));
    let pbytes = param_bytes(ds.spec.dim, 256, ds.spec.classes, base_cfg.fanouts.len());
    let allreduce_frac = if workers > 1 { 2.0 * (workers - 1) as f64 / workers as f64 } else { 0.0 };
    let allreduce = Duration::from_secs_f64(
        allreduce_frac * pbytes as f64 / machine.cfg.pcie.bandwidth
            + if workers > 1 { 30e-6 } else { 0.0 },
    );

    // Build every worker's engine up front (OOM here is a result).
    let mut engines = Vec::with_capacity(workers);
    for w in 0..workers {
        let mut cfg = base_cfg.clone();
        cfg.segment = Some((w, workers));
        cfg.seed = base_cfg.seed.wrapping_add(w as u64);
        let caps = crate::baselines::shared_caps(machine, ds, &cfg, variant);
        let gpu = match variant {
            Variant::Gpu => machine.cfg.gpu,
            Variant::Cpu => GpuModel::CpuOnly,
        };
        let inner = SimTrainStep::new(
            gpu,
            machine.clock.clone(),
            model,
            caps,
            cfg.fanouts.clone(),
            ds.spec.dim,
            256,
            ds.spec.classes,
        );
        let trainer = Box::new(SyncedTrainStep {
            inner: Box::new(inner),
            group: group.clone(),
            worker: w,
            allreduce,
            clock: machine.clock.clone(),
            step_no: 0,
        });
        engines.push(GnnDrive::new_on_device(machine, ds, cfg, variant, w, trainer)?);
    }

    let sw = crate::sim::Stopwatch::start(&machine.clock);
    let stats: Vec<EpochStats> = std::thread::scope(|s| {
        let handles: Vec<_> = engines
            .iter()
            .enumerate()
            .map(|(w, engine)| {
                let group = group.clone();
                s.spawn(move || {
                    let st = engine.run_epoch(epoch);
                    group.finished(w);
                    st
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    Ok(ScalingPoint {
        workers,
        epoch_time: sw.elapsed(),
        batches: stats.iter().map(|s| s.batches).sum(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::graph::DatasetSpec;
    use crate::sim::Clock;

    #[test]
    fn two_workers_split_batches_and_finish() {
        let machine = Arc::new(Machine::new(MachineConfig::k80(), Clock::new(0.05)));
        let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
        let cfg = TrainConfig {
            batch_size: 64,
            fanouts: vec![4, 4],
            batches_per_epoch: Some(3),
            samplers: 1,
            extractors: 2,
            io_depth: 32,
            ..TrainConfig::default()
        };
        let one = run_parallel_epoch(
            &machine,
            &ds,
            &cfg,
            ModelKind::GraphSage,
            Variant::Gpu,
            1,
            0,
        )
        .unwrap();
        let two = run_parallel_epoch(
            &machine,
            &ds,
            &cfg,
            ModelKind::GraphSage,
            Variant::Gpu,
            2,
            0,
        )
        .unwrap();
        assert_eq!(one.batches, 3);
        assert_eq!(two.batches, 6); // each worker caps batches_per_epoch
        assert!(two.epoch_time.as_nanos() > 0);
        // All reservations released.
        assert_eq!(machine.host.reserved(), (ds.graph.indptr.len() * 8) as u64);
        for d in &machine.devices {
            assert_eq!(d.reserved(), 0);
        }
    }

    #[test]
    fn param_bytes_reasonable() {
        let b = param_bytes(128, 256, 172, 3);
        // l0: 128→256, l1: 256→256, l2: 256→172 (×2 weights each + bias)
        assert!(b > 500_000 && b < 2_000_000, "b={b}");
    }
}
