//! Loose step barrier for data-parallel gradient synchronization.
//!
//! Workers arrive at step k and block until every *active* worker has
//! reached step k; a worker that finishes its segment calls `finished` and
//! leaves the group, so uneven segment sizes never deadlock (the real
//! system's DDP join semantics).

use std::collections::HashMap;
use std::sync::{Condvar, Mutex};

pub struct SyncGroup {
    state: Mutex<State>,
    cv: Condvar,
}

struct State {
    /// worker → completed-step count (None once finished).
    steps: HashMap<usize, Option<u64>>,
}

impl SyncGroup {
    pub fn new(workers: usize) -> Self {
        SyncGroup {
            state: Mutex::new(State {
                steps: (0..workers).map(|w| (w, Some(0))).collect(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Worker `w` completed step `step` (0-based); blocks until all active
    /// workers have completed it too.
    pub fn arrive(&self, w: usize, step: u64) {
        let mut st = self.state.lock().unwrap();
        if let Some(Some(s)) = st.steps.get_mut(&w) {
            *s = step + 1;
        }
        self.cv.notify_all();
        loop {
            let all_reached = st
                .steps
                .values()
                .all(|v| match v {
                    Some(s) => *s >= step + 1,
                    None => true, // finished workers don't hold others back
                });
            if all_reached {
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Worker `w` has no more steps; release anyone waiting on it.
    pub fn finished(&self, w: usize) {
        let mut st = self.state.lock().unwrap();
        if let Some(v) = st.steps.get_mut(&w) {
            *v = None;
        }
        drop(st);
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn workers_stay_in_lockstep() {
        let g = Arc::new(SyncGroup::new(3));
        let max_skew = Arc::new(Mutex::new(0i64));
        let counters: Arc<Vec<Mutex<i64>>> =
            Arc::new((0..3).map(|_| Mutex::new(0)).collect());
        let handles: Vec<_> = (0..3)
            .map(|w| {
                let g = g.clone();
                let counters = counters.clone();
                let max_skew = max_skew.clone();
                std::thread::spawn(move || {
                    for step in 0..20u64 {
                        std::thread::sleep(Duration::from_micros(50 * (w as u64 + 1)));
                        *counters[w].lock().unwrap() = step as i64;
                        // Observe skew before syncing.
                        let vals: Vec<i64> =
                            counters.iter().map(|c| *c.lock().unwrap()).collect();
                        let skew = vals.iter().max().unwrap() - vals.iter().min().unwrap();
                        let mut ms = max_skew.lock().unwrap();
                        *ms = (*ms).max(skew);
                        drop(ms);
                        g.arrive(w, step);
                    }
                    g.finished(w);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // With a per-step barrier, skew can never exceed 1 full step.
        assert!(*max_skew.lock().unwrap() <= 1 + 1, "skew too large");
    }

    #[test]
    fn finished_worker_does_not_block_others() {
        let g = Arc::new(SyncGroup::new(2));
        let g2 = g.clone();
        let h = std::thread::spawn(move || {
            g2.arrive(1, 0);
            g2.finished(1); // worker 1 stops after one step
        });
        g.arrive(0, 0);
        h.join().unwrap();
        // Worker 0 continues alone without deadlock.
        g.arrive(0, 1);
        g.arrive(0, 2);
        g.finished(0);
    }
}
