//! Disk-resident CSC topology.
//!
//! Mirrors the paper's layout (§4.1/§5): the *index pointer* array (`indptr`,
//! one u64 per node) is pinned in host memory — it is small and hot during
//! sampling — while the *index* array (`indices`, one u32 per edge) lives on
//! SSD and is accessed through the OS page cache (mmap-style), where it
//! contends with whatever else occupies host memory.

use crate::storage::{HostMemory, IoBackend, Reservation, SimFile};
use std::sync::Arc;

pub struct DiskGraph {
    pub nodes: u32,
    pub indptr: Arc<Vec<u64>>,
    pub indices_file: SimFile,
    /// Host-memory reservation pinning `indptr` (paper: <1 GB, kept in RAM).
    _indptr_reservation: Option<Reservation>,
}

impl DiskGraph {
    pub fn new(
        nodes: u32,
        indptr: Arc<Vec<u64>>,
        indices_file: SimFile,
        host: Option<&HostMemory>,
    ) -> Result<Self, crate::storage::OutOfMemory> {
        assert_eq!(indptr.len(), nodes as usize + 1);
        let reservation = match host {
            Some(h) => Some(h.reserve("topology indptr", (indptr.len() * 8) as u64)?),
            None => None,
        };
        Ok(DiskGraph { nodes, indptr, indices_file, _indptr_reservation: reservation })
    }

    pub fn edges(&self) -> u64 {
        *self.indptr.last().unwrap()
    }

    pub fn degree(&self, v: u32) -> u64 {
        self.indptr[v as usize + 1] - self.indptr[v as usize]
    }

    /// Read v's in-neighbor list from SSD through the backend's buffered
    /// path (mmap semantics), appending into `out`. This is the
    /// sampling-side I/O that memory contention (D1) slows down.
    pub fn neighbors_into(&self, io: &dyn IoBackend, v: u32, out: &mut Vec<u32>) {
        let mut scratch = Vec::new();
        self.neighbors_into_scratch(io, v, out, &mut scratch);
    }

    /// Allocation-free variant: the caller supplies a reusable byte scratch
    /// (the sampler hot loop reads ~10⁴ lists per mini-batch).
    pub fn neighbors_into_scratch(
        &self,
        io: &dyn IoBackend,
        v: u32,
        out: &mut Vec<u32>,
        scratch: &mut Vec<u8>,
    ) {
        let start = self.indptr[v as usize];
        let end = self.indptr[v as usize + 1];
        let deg = (end - start) as usize;
        if deg == 0 {
            return;
        }
        scratch.clear();
        scratch.resize(deg * 4, 0);
        io.read_buffered(&self.indices_file, start * 4, scratch);
        out.reserve(deg);
        for b in scratch.chunks_exact(4) {
            out.push(u32::from_le_bytes(b.try_into().unwrap()));
        }
    }

    /// Convenience wrapper allocating a fresh vec.
    pub fn neighbors(&self, io: &dyn IoBackend, v: u32) -> Vec<u32> {
        let mut out = Vec::new();
        self.neighbors_into(io, v, &mut out);
        out
    }

    /// Read v's in-neighbors *without* charging device time — used when a
    /// system holds this adjacency in its own in-memory cache (Ginex's
    /// neighbor cache, MariusGNN's buffered partitions).
    pub fn neighbors_into_nocharge(&self, v: u32, out: &mut Vec<u32>) {
        let start = self.indptr[v as usize];
        let end = self.indptr[v as usize + 1];
        let deg = (end - start) as usize;
        if deg == 0 {
            return;
        }
        let mut buf = vec![0u8; deg * 4];
        self.indices_file.backing.read_at(start * 4, &mut buf);
        out.reserve(deg);
        for b in buf.chunks_exact(4) {
            out.push(u32::from_le_bytes(b.try_into().unwrap()));
        }
    }

    /// Topology bytes on SSD (the indices array).
    pub fn topo_bytes(&self) -> u64 {
        self.indices_file.len()
    }
}

impl std::fmt::Debug for DiskGraph {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DiskGraph")
            .field("nodes", &self.nodes)
            .field("edges", &self.edges())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;
    use crate::storage::{
        DataKind, FileId, MemBacking, PageCache, SsdConfig, SsdSim, Storage,
    };

    fn storage() -> Storage {
        let clock = Clock::new(0.1);
        let ssd = SsdSim::new(SsdConfig::pm883(), clock);
        let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
        Storage::new(ssd, cache)
    }

    fn tiny_graph(host: Option<&HostMemory>) -> DiskGraph {
        // 3 nodes: in-neighbors 0←{1,2}, 1←{0}, 2←{} .
        let indptr = Arc::new(vec![0u64, 2, 3, 3]);
        let indices = MemBacking::from_u32s(&[1, 2, 0]);
        let file = SimFile::new(FileId::new(0, DataKind::Topology), Arc::new(indices));
        DiskGraph::new(3, indptr, file, host).unwrap()
    }

    #[test]
    fn neighbors_roundtrip() {
        let st = storage();
        let g = tiny_graph(None);
        assert_eq!(g.edges(), 3);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.neighbors(&st, 0), vec![1, 2]);
        assert_eq!(g.neighbors(&st, 1), vec![0]);
        assert!(g.neighbors(&st, 2).is_empty());
    }

    #[test]
    fn indptr_reserves_host_memory() {
        let host = HostMemory::new(1 << 20);
        let _g = tiny_graph(Some(&host));
        assert_eq!(host.reserved(), 4 * 8);
    }

    #[test]
    fn neighbor_reads_hit_page_cache_second_time() {
        let st = storage();
        let g = tiny_graph(None);
        g.neighbors(&st, 0);
        let reads = st.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed);
        g.neighbors(&st, 0);
        assert_eq!(
            st.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            reads
        );
    }
}
