//! Graph substrate: synthetic generation, disk-resident CSC topology,
//! on-SSD feature tables, dataset registry (paper Table 1 analogs).

pub mod dataset;
pub mod disk;
pub mod features;
pub mod gen;

pub use dataset::{Dataset, DatasetSpec};
pub use disk::DiskGraph;
pub use features::{FeatureGen, FeatureTable};
