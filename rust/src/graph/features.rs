//! Feature table: planted-signal feature generation + on-SSD row layout.
//!
//! Features are `f32` rows of `dim` per node, stored row-major in ascending
//! node-id order (exactly the paper's layout, §4.1). Row `v` of class `c`
//! is `centroid[c] + noise(v)` — a planted linear signal strong enough for a
//! GNN to learn (Fig 14) yet cheap to synthesize on demand. The table backs
//! either a [`ProceduralBacking`] (zero disk, deterministic) or a real file
//! written once (the end-to-end example).

use crate::storage::backing::{ProceduralBacking, StripeSpec};
use crate::storage::{BackingRef, FileId, SimFile};
use crate::util::rng::{hash2, hash_normal};
use std::sync::Arc;

/// Deterministic feature synthesizer shared by the procedural backing and
/// the file writer.
#[derive(Clone)]
pub struct FeatureGen {
    seed: u64,
    dim: usize,
    noise: f32,
    /// `classes × dim` centroid matrix (small; precomputed).
    centroids: Arc<Vec<f32>>,
    labels: Arc<Vec<u16>>,
}

impl FeatureGen {
    pub fn new(seed: u64, dim: usize, classes: usize, noise: f32, labels: Arc<Vec<u16>>) -> Self {
        let mut centroids = Vec::with_capacity(classes * dim);
        for c in 0..classes {
            for j in 0..dim {
                centroids.push(hash_normal(seed ^ 0xCE47801D, (c * dim + j) as u64));
            }
        }
        FeatureGen { seed, dim, noise, centroids: Arc::new(centroids), labels }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Centroid value for (class, feature) — exposed for tests/oracles.
    pub fn centroid(&self, class: usize, j: usize) -> f32 {
        self.centroids[class * self.dim + j]
    }

    pub fn row_bytes(&self) -> u64 {
        (self.dim * 4) as u64
    }

    /// Fill one node's feature row (as f32 little-endian bytes).
    pub fn fill_row(&self, v: u64, out: &mut [u8]) {
        let label = *self.labels.get(v as usize).unwrap_or(&0) as usize;
        let base = label * self.dim;
        // Noise: cheap uniform in [-√3, √3] (unit variance) from one hash per
        // element — gaussian quality is unnecessary and 10× the cost.
        const SQRT3: f32 = 1.732_050_8;
        for j in 0..self.dim.min(out.len() / 4) {
            let h = hash2(self.seed ^ 0x0F0F, v * self.dim as u64 + j as u64);
            let u = (h >> 40) as f32 * (1.0 / (1u64 << 24) as f32); // [0,1)
            let x = self.centroids[base + j] + self.noise * (2.0 * u - 1.0) * SQRT3;
            out[j * 4..j * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
    }

    /// Decode a row previously produced by `fill_row` (or read from SSD).
    pub fn decode_row(bytes: &[u8]) -> Vec<f32> {
        bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect()
    }
}

/// The on-SSD feature table.
#[derive(Clone)]
pub struct FeatureTable {
    pub file: SimFile,
    pub dim: usize,
    pub nodes: u64,
}

impl FeatureTable {
    /// Procedural table (no disk space; see DESIGN.md §3).
    pub fn procedural(file_id: FileId, nodes: u64, gen: FeatureGen) -> Self {
        let dim = gen.dim();
        let row = gen.row_bytes();
        let backing: BackingRef = Arc::new(ProceduralBacking::new(
            nodes * row,
            row,
            move |chunk, out| gen.fill_row(chunk, out),
        ));
        FeatureTable { file: SimFile::new(file_id, backing), dim, nodes }
    }

    /// Wrap an existing backing (e.g. a real file written by `write_file`).
    pub fn from_backing(file_id: FileId, nodes: u64, dim: usize, backing: BackingRef) -> Self {
        FeatureTable { file: SimFile::new(file_id, backing), dim, nodes }
    }

    /// Materialize the table into a real file (streamed; used by the e2e
    /// example and `gnndrive gen-data`).
    pub fn write_file(
        path: &std::path::Path,
        nodes: u64,
        gen: &FeatureGen,
    ) -> std::io::Result<()> {
        use std::io::Write;
        let f = std::fs::File::create(path)?;
        let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
        let mut row = vec![0u8; gen.row_bytes() as usize];
        for v in 0..nodes {
            gen.fill_row(v, &mut row);
            w.write_all(&row)?;
        }
        w.flush()
    }

    /// Materialize the table RAID-0-striped across `paths.len()` member
    /// files in `stripe_bytes` chunks (`gen-data --devices N`). Rows stream
    /// in logical order and each row's bytes are split at chunk boundaries
    /// to the owning member — a device's local offsets are monotone in the
    /// logical offset, so every member file is a pure sequential append.
    /// One path degenerates to [`FeatureTable::write_file`] byte-for-byte.
    pub fn write_file_striped(
        paths: &[std::path::PathBuf],
        nodes: u64,
        gen: &FeatureGen,
        stripe_bytes: u64,
    ) -> std::io::Result<()> {
        use std::io::Write;
        assert!(!paths.is_empty(), "striped feature table needs at least one member file");
        let spec = StripeSpec::new(paths.len(), stripe_bytes);
        let mut writers = Vec::with_capacity(paths.len());
        for p in paths {
            writers.push(std::io::BufWriter::with_capacity(1 << 20, std::fs::File::create(p)?));
        }
        let mut row = vec![0u8; gen.row_bytes() as usize];
        let mut off = 0u64;
        for v in 0..nodes {
            gen.fill_row(v, &mut row);
            let mut taken = 0usize;
            for (dev, _local, run) in spec.split(off, row.len()) {
                writers[dev].write_all(&row[taken..taken + run])?;
                taken += run;
            }
            off += row.len() as u64;
        }
        for mut w in writers {
            w.flush()?;
        }
        Ok(())
    }

    pub fn row_bytes(&self) -> u64 {
        (self.dim * 4) as u64
    }

    pub fn row_offset(&self, v: u64) -> u64 {
        v * self.row_bytes()
    }

    pub fn total_bytes(&self) -> u64 {
        self.nodes * self.row_bytes()
    }
}

impl std::fmt::Debug for FeatureTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeatureTable")
            .field("nodes", &self.nodes)
            .field("dim", &self.dim)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::backing::FileBacking;
    use crate::storage::DataKind;

    fn labels(n: usize, classes: u16) -> Arc<Vec<u16>> {
        Arc::new((0..n).map(|v| (v as u16) % classes).collect())
    }

    #[test]
    fn rows_are_deterministic_and_class_separated() {
        let gen = FeatureGen::new(7, 16, 4, 0.1, labels(100, 4));
        let mut a = vec![0u8; 64];
        let mut b = vec![0u8; 64];
        gen.fill_row(5, &mut a);
        gen.fill_row(5, &mut b);
        assert_eq!(a, b);
        // Same class (5 and 9, both label 1 with classes=4): rows are close.
        gen.fill_row(9, &mut b);
        let xa = FeatureGen::decode_row(&a);
        let xb = FeatureGen::decode_row(&b);
        let same: f32 = xa.iter().zip(&xb).map(|(p, q)| (p - q).abs()).sum();
        // Different class (label 2): rows are far.
        let mut c = vec![0u8; 64];
        gen.fill_row(6, &mut c);
        let xc = FeatureGen::decode_row(&c);
        let diff: f32 = xa.iter().zip(&xc).map(|(p, q)| (p - q).abs()).sum();
        assert!(diff > same * 2.0, "same={same} diff={diff}");
    }

    #[test]
    fn noise_statistics() {
        let gen = FeatureGen::new(3, 64, 2, 0.5, labels(1000, 2));
        // Mean over many same-class rows converges to the centroid.
        let mut acc = vec![0f64; 64];
        let m = 200;
        let mut row = vec![0u8; 256];
        for v in (0..2 * m).step_by(2) {
            gen.fill_row(v as u64, &mut row);
            for (j, x) in FeatureGen::decode_row(&row).iter().enumerate() {
                acc[j] += *x as f64;
            }
        }
        let mut err = 0f64;
        for (j, a) in acc.iter().enumerate() {
            let mean = a / m as f64;
            err += (mean - gen.centroid(0, j) as f64).abs();
        }
        assert!(err / 64.0 < 0.12, "avg centroid error {}", err / 64.0);
    }

    #[test]
    fn procedural_table_serves_rows() {
        let gen = FeatureGen::new(11, 32, 4, 0.2, labels(50, 4));
        let table = FeatureTable::procedural(FileId::new(3, DataKind::Features), 50, gen.clone());
        assert_eq!(table.total_bytes(), 50 * 128);
        let mut direct = vec![0u8; 128];
        gen.fill_row(17, &mut direct);
        let mut via_table = vec![0u8; 128];
        table.file.backing.read_at(table.row_offset(17), &mut via_table);
        assert_eq!(direct, via_table);
    }

    #[test]
    fn striped_files_roundtrip_through_striped_backing() {
        use crate::storage::backing::StripedBacking;
        // 8 f32 → 32-byte rows; 48-byte chunks on 3 members: rows straddle
        // chunk (and so device) boundaries regularly.
        let gen = FeatureGen::new(31, 8, 2, 0.3, labels(40, 2));
        let dir = std::env::temp_dir().join("gnndrive_feat_striped_test");
        std::fs::create_dir_all(&dir).unwrap();
        let paths: Vec<std::path::PathBuf> =
            (0..3).map(|d| dir.join(format!("feat.bin.{d}"))).collect();
        FeatureTable::write_file_striped(&paths, 40, &gen, 48).unwrap();
        let members: Vec<BackingRef> = paths
            .iter()
            .map(|p| Arc::new(FileBacking::open(p).unwrap()) as BackingRef)
            .collect();
        let striped = StripedBacking::new(members, 48);
        use crate::storage::Backing;
        assert_eq!(striped.len(), 40 * 32, "member lengths must sum to the logical size");
        let backing: BackingRef = Arc::new(striped);
        let table =
            FeatureTable::from_backing(FileId::new(5, DataKind::Features), 40, 8, backing);
        let mut expect = vec![0u8; 32];
        let mut got = vec![0u8; 32];
        for v in 0..40u64 {
            gen.fill_row(v, &mut expect);
            table.file.backing.read_at(table.row_offset(v), &mut got);
            assert_eq!(expect, got, "row {v}");
        }
    }

    #[test]
    fn file_roundtrip_matches_procedural() {
        let gen = FeatureGen::new(23, 8, 2, 0.3, labels(20, 2));
        let dir = std::env::temp_dir().join("gnndrive_feat_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("feat.bin");
        FeatureTable::write_file(&path, 20, &gen).unwrap();
        let backing: BackingRef = Arc::new(FileBacking::open(&path).unwrap());
        let table = FeatureTable::from_backing(FileId::new(4, DataKind::Features), 20, 8, backing);
        let mut expect = vec![0u8; 32];
        let mut got = vec![0u8; 32];
        for v in [0u64, 7, 19] {
            gen.fill_row(v, &mut expect);
            table.file.backing.read_at(table.row_offset(v), &mut got);
            assert_eq!(expect, got, "row {v}");
        }
    }
}
