//! Dataset registry and materialization.
//!
//! Each paper dataset gets a 1/256-scale synthetic analog with matched
//! byte ratios (DESIGN.md §8 / paper Table 1). A [`Dataset`] bundles the
//! disk-resident topology, the on-SSD feature table, in-memory labels and
//! the train split; `materialize` builds it against a [`Machine`]'s storage
//! substrate, and `write_dir`/`load_dir` persist a real on-disk copy for the
//! end-to-end example.

use super::disk::DiskGraph;
use super::features::{FeatureGen, FeatureTable};
use super::gen::{generate, GraphGenSpec};
use crate::config::Machine;
use crate::storage::{
    BackingRef, DataKind, FileBacking, FileId, MemBacking, StripeSpec, StripedBacking,
};
use crate::util::rng::hash2;
use std::path::Path;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// Unique simulated-file ids across the process.
fn next_file_id() -> u32 {
    static NEXT: AtomicU32 = AtomicU32::new(100);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: String,
    pub nodes: u32,
    pub avg_degree: f64,
    pub dim: usize,
    pub classes: usize,
    pub train_frac: f64,
    pub community_size: u32,
    pub homophily: f64,
    pub degree_alpha: f64,
    pub noise: f32,
    pub seed: u64,
}

impl DatasetSpec {
    /// Papers100M analog (111 M nodes / 1.6 B edges / dim 128 / 172 classes).
    pub fn papers100m_mini() -> Self {
        DatasetSpec {
            name: "papers100m-mini".into(),
            nodes: 433_000,
            avg_degree: 29.0,
            dim: 128,
            classes: 172,
            train_frac: 0.05,
            community_size: 400,
            homophily: 0.55,
            degree_alpha: 2.0,
            noise: 0.7,
            seed: 0x9A9E85,
        }
    }

    /// Twitter analog (41.7 M / 1.5 B / 128 / 50).
    pub fn twitter_mini() -> Self {
        DatasetSpec {
            name: "twitter-mini".into(),
            nodes: 163_000,
            avg_degree: 66.0,
            dim: 128,
            classes: 50,
            train_frac: 0.05,
            community_size: 250,
            homophily: 0.45,
            degree_alpha: 1.9, // heavier tail: social-network hubs
            noise: 0.7,
            seed: 0x7417E8,
        }
    }

    /// Friendster analog (65.6 M / 1.8 B / 128 / 50).
    pub fn friendster_mini() -> Self {
        DatasetSpec {
            name: "friendster-mini".into(),
            nodes: 256_000,
            avg_degree: 53.0,
            dim: 128,
            classes: 50,
            train_frac: 0.05,
            community_size: 320,
            homophily: 0.5,
            degree_alpha: 2.1,
            seed: 0xF81E9D,
            noise: 0.7,
        }
    }

    /// MAG240M analog (122 M paper nodes / 1.3 B edges / dim 768 / 153).
    pub fn mag240m_mini() -> Self {
        DatasetSpec {
            name: "mag240m-mini".into(),
            nodes: 475_000,
            avg_degree: 21.0,
            dim: 768,
            classes: 153,
            train_frac: 0.02,
            community_size: 500,
            homophily: 0.55,
            degree_alpha: 2.0,
            noise: 0.7,
            seed: 0x3A9240,
        }
    }

    /// Tiny real-file dataset for the end-to-end PJRT-training example.
    pub fn papers_tiny() -> Self {
        DatasetSpec {
            name: "papers-tiny".into(),
            nodes: 60_000,
            avg_degree: 20.0,
            dim: 64,
            classes: 16,
            train_frac: 0.1,
            community_size: 200,
            homophily: 0.6,
            degree_alpha: 2.1,
            noise: 0.5,
            seed: 0x7142,
        }
    }

    /// Miniature spec for unit tests.
    pub fn unit_test() -> Self {
        DatasetSpec {
            name: "unit-test".into(),
            nodes: 3_000,
            avg_degree: 10.0,
            dim: 16,
            classes: 4,
            train_frac: 0.2,
            community_size: 100,
            homophily: 0.6,
            degree_alpha: 2.2,
            noise: 0.4,
            seed: 0x0707,
        }
    }

    /// Look up a spec by CLI name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "papers100m-mini" => Some(Self::papers100m_mini()),
            "twitter-mini" => Some(Self::twitter_mini()),
            "friendster-mini" => Some(Self::friendster_mini()),
            "mag240m-mini" => Some(Self::mag240m_mini()),
            "papers-tiny" => Some(Self::papers_tiny()),
            "unit-test" => Some(Self::unit_test()),
            _ => None,
        }
    }

    pub fn all_minis() -> Vec<Self> {
        vec![
            Self::papers100m_mini(),
            Self::twitter_mini(),
            Self::friendster_mini(),
            Self::mag240m_mini(),
        ]
    }

    /// Dimension override (Fig 2/8/9 sweep 64–512).
    pub fn with_dim(mut self, dim: usize) -> Self {
        self.dim = dim;
        self
    }

    /// Expected feature bytes on SSD.
    pub fn feature_bytes(&self) -> u64 {
        self.nodes as u64 * (self.dim as u64) * 4
    }

    fn gen_spec(&self) -> GraphGenSpec {
        GraphGenSpec {
            nodes: self.nodes,
            avg_degree: self.avg_degree,
            degree_alpha: self.degree_alpha,
            classes: self.classes,
            community_size: self.community_size,
            homophily: self.homophily,
            seed: self.seed,
        }
    }

    /// Deterministic train split: node v trains iff hash(v) < frac·2⁶⁴.
    pub fn train_ids(&self) -> Vec<u32> {
        let threshold = (self.train_frac * u64::MAX as f64) as u64;
        (0..self.nodes)
            .filter(|&v| hash2(self.seed ^ 0x5917, v as u64) < threshold)
            .collect()
    }
}

/// A materialized dataset bound to a machine's storage substrate.
pub struct Dataset {
    pub spec: DatasetSpec,
    pub graph: DiskGraph,
    pub features: FeatureTable,
    pub labels: Arc<Vec<u16>>,
    pub train_ids: Vec<u32>,
    pub feature_gen: FeatureGen,
}

impl Dataset {
    /// Build the synthetic analog in memory (topology) + procedurally
    /// (features), charging the indptr pin to the machine's host memory.
    pub fn materialize(spec: &DatasetSpec, machine: &Machine) -> anyhow::Result<Dataset> {
        let g = generate(&spec.gen_spec());
        let labels = Arc::new(g.labels);
        let indices_backing: BackingRef = Arc::new(MemBacking::from_u32s(&g.indices));
        let indices_file = crate::storage::SimFile::new(
            FileId::new(next_file_id(), DataKind::Topology),
            indices_backing,
        );
        let graph = DiskGraph::new(
            spec.nodes,
            Arc::new(g.indptr),
            indices_file,
            Some(&machine.host),
        )?;
        let feature_gen =
            FeatureGen::new(spec.seed, spec.dim, spec.classes, spec.noise, labels.clone());
        let features = FeatureTable::procedural(
            FileId::new(next_file_id(), DataKind::Features),
            spec.nodes as u64,
            feature_gen.clone(),
        );
        Ok(Dataset {
            train_ids: spec.train_ids(),
            spec: spec.clone(),
            graph,
            features,
            labels,
            feature_gen,
        })
    }

    /// Write a real on-disk copy (indptr/indices/labels/features/meta).
    pub fn write_dir(spec: &DatasetSpec, dir: &Path) -> anyhow::Result<()> {
        Self::write_dir_striped(spec, dir, 1, 1 << 20)
    }

    /// Write an on-disk copy whose feature table stripes across `devices`
    /// member files (`features.bin.0 … .N-1`) in `stripe_bytes` chunks
    /// (`gen-data --devices N --stripe-bytes B`). The geometry is recorded
    /// in `meta.toml` (`stripe_devices` / `stripe_bytes`) and must match
    /// the machine flags at load time. Topology/label files stay unstriped
    /// — only the feature table carries the random-read load the stripe
    /// exists for. `devices == 1` is exactly [`Dataset::write_dir`].
    pub fn write_dir_striped(
        spec: &DatasetSpec,
        dir: &Path,
        devices: usize,
        stripe_bytes: u64,
    ) -> anyhow::Result<()> {
        let devices = devices.max(1);
        let stripe_bytes = stripe_bytes.max(1);
        std::fs::create_dir_all(dir)?;
        let g = generate(&spec.gen_spec());
        let labels = Arc::new(g.labels);
        write_slice_u64(&dir.join("indptr.bin"), &g.indptr)?;
        write_slice_u32(&dir.join("indices.bin"), &g.indices)?;
        write_slice_u16(&dir.join("labels.bin"), &labels)?;
        let gen = FeatureGen::new(spec.seed, spec.dim, spec.classes, spec.noise, labels.clone());
        let mut meta = format!(
            "name = \"{}\"\nnodes = {}\ndim = {}\nclasses = {}\ntrain_frac = {}\nseed = {}\n\
             avg_degree = {}\ncommunity_size = {}\nhomophily = {}\ndegree_alpha = {}\nnoise = {}\n",
            spec.name,
            spec.nodes,
            spec.dim,
            spec.classes,
            spec.train_frac,
            spec.seed,
            spec.avg_degree,
            spec.community_size,
            spec.homophily,
            spec.degree_alpha,
            spec.noise,
        );
        if devices > 1 {
            let paths: Vec<std::path::PathBuf> =
                (0..devices).map(|d| dir.join(format!("features.bin.{d}"))).collect();
            FeatureTable::write_file_striped(&paths, spec.nodes as u64, &gen, stripe_bytes)?;
            meta.push_str(&format!(
                "stripe_devices = {devices}\nstripe_bytes = {stripe_bytes}\n"
            ));
        } else {
            FeatureTable::write_file(&dir.join("features.bin"), spec.nodes as u64, &gen)?;
        }
        std::fs::write(dir.join("meta.toml"), meta)?;
        Ok(())
    }

    /// Load a dataset previously written with `write_dir`; features are
    /// served from the real file (exercising the file-backed path).
    pub fn load_dir(dir: &Path, machine: &Machine) -> anyhow::Result<Dataset> {
        let meta = crate::util::toml::Doc::parse(&std::fs::read_to_string(dir.join("meta.toml"))?)
            .map_err(anyhow::Error::msg)?;
        let spec = DatasetSpec {
            name: meta.get_str("name").unwrap_or("loaded").to_string(),
            nodes: meta.get_i64("nodes").ok_or_else(|| anyhow::anyhow!("meta: nodes"))? as u32,
            dim: meta.get_i64("dim").ok_or_else(|| anyhow::anyhow!("meta: dim"))? as usize,
            classes: meta.get_i64("classes").ok_or_else(|| anyhow::anyhow!("meta: classes"))?
                as usize,
            train_frac: meta.get_f64("train_frac").unwrap_or(0.1),
            seed: meta.get_i64("seed").unwrap_or(0) as u64,
            avg_degree: meta.get_f64("avg_degree").unwrap_or(20.0),
            community_size: meta.get_i64("community_size").unwrap_or(100) as u32,
            homophily: meta.get_f64("homophily").unwrap_or(0.5),
            degree_alpha: meta.get_f64("degree_alpha").unwrap_or(2.1),
            noise: meta.get_f64("noise").unwrap_or(0.5) as f32,
        };
        let indptr = Arc::new(read_slice_u64(&dir.join("indptr.bin"))?);
        let labels = Arc::new(read_slice_u16(&dir.join("labels.bin"))?);
        let indices_backing: BackingRef =
            Arc::new(FileBacking::open(&dir.join("indices.bin"))?);
        let indices_file = crate::storage::SimFile::new(
            FileId::new(next_file_id(), DataKind::Topology),
            indices_backing,
        );
        let graph = DiskGraph::new(spec.nodes, indptr, indices_file, Some(&machine.host))?;
        // Stripe geometry handshake: the dataset was written with a fixed
        // geometry; the machine's queues/charging must be configured to the
        // same one or logical↔device translation would diverge.
        let stripe_devices = meta.get_i64("stripe_devices").unwrap_or(1).max(1) as usize;
        let meta_stripe_bytes = meta.get_i64("stripe_bytes").unwrap_or(1).max(1) as u64;
        let ds_spec = StripeSpec::new(stripe_devices, meta_stripe_bytes);
        let m_spec = machine.cfg.stripe_spec();
        if ds_spec != m_spec {
            anyhow::bail!(
                "dataset stripe geometry mismatch: meta.toml expects {} device(s) with \
                 stripe {} B, but the CLI (--devices/--stripe-bytes) configured {} device(s) \
                 with stripe {} B; pass matching flags or regenerate with `gen-data --devices …`",
                ds_spec.devices,
                ds_spec.stripe_bytes,
                m_spec.devices,
                m_spec.stripe_bytes,
            );
        }
        let feature_backing: BackingRef = if stripe_devices > 1 {
            let mut members: Vec<BackingRef> = Vec::with_capacity(stripe_devices);
            for d in 0..stripe_devices {
                members
                    .push(Arc::new(FileBacking::open(&dir.join(format!("features.bin.{d}")))?));
            }
            Arc::new(StripedBacking::new(members, meta_stripe_bytes))
        } else {
            Arc::new(FileBacking::open(&dir.join("features.bin"))?)
        };
        let features = FeatureTable::from_backing(
            FileId::new(next_file_id(), DataKind::Features),
            spec.nodes as u64,
            spec.dim,
            feature_backing,
        );
        let feature_gen =
            FeatureGen::new(spec.seed, spec.dim, spec.classes, spec.noise, labels.clone());
        Ok(Dataset {
            train_ids: spec.train_ids(),
            spec,
            graph,
            features,
            labels,
            feature_gen,
        })
    }

    /// Paper-style Table 1 row: name, nodes, edges, dim, classes, topo/feat MB.
    pub fn table1_row(&self) -> String {
        format!(
            "{:<18} {:>9} {:>10} {:>5} {:>7} {:>10} {:>10}",
            self.spec.name,
            self.spec.nodes,
            self.graph.edges(),
            self.spec.dim,
            self.spec.classes,
            crate::util::units::fmt_bytes(self.graph.topo_bytes()),
            crate::util::units::fmt_bytes(self.features.total_bytes()),
        )
    }
}

fn write_slice_u64(path: &Path, xs: &[u64]) -> std::io::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()
}

fn write_slice_u32(path: &Path, xs: &[u32]) -> std::io::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()
}

fn write_slice_u16(path: &Path, xs: &[u16]) -> std::io::Result<()> {
    use std::io::Write;
    let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
    for x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    w.flush()
}

fn read_slice_u64(path: &Path) -> std::io::Result<Vec<u64>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes.chunks_exact(8).map(|b| u64::from_le_bytes(b.try_into().unwrap())).collect())
}

fn read_slice_u16(path: &Path) -> std::io::Result<Vec<u16>> {
    let bytes = std::fs::read(path)?;
    Ok(bytes.chunks_exact(2).map(|b| u16::from_le_bytes(b.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::sim::Clock;

    fn machine() -> Machine {
        Machine::new(MachineConfig::paper(), Clock::new(0.1))
    }

    #[test]
    fn registry_resolves_names() {
        for name in [
            "papers100m-mini",
            "twitter-mini",
            "friendster-mini",
            "mag240m-mini",
            "papers-tiny",
            "unit-test",
        ] {
            assert!(DatasetSpec::by_name(name).is_some(), "{name}");
        }
        assert!(DatasetSpec::by_name("nope").is_none());
    }

    #[test]
    fn materialize_unit_test_dataset() {
        let m = machine();
        let ds = Dataset::materialize(&DatasetSpec::unit_test(), &m).unwrap();
        assert_eq!(ds.graph.nodes, 3000);
        assert!(ds.graph.edges() > 20_000);
        assert_eq!(ds.labels.len(), 3000);
        let expected = (3000.0 * 0.2) as f64;
        assert!((ds.train_ids.len() as f64 - expected).abs() < expected * 0.25);
        // Features readable and deterministic.
        let mut a = vec![0u8; 64];
        ds.features.file.backing.read_at(ds.features.row_offset(10), &mut a);
        let mut b = vec![0u8; 64];
        ds.feature_gen.fill_row(10, &mut b);
        assert_eq!(a, b);
        // indptr pinned in host memory.
        assert!(m.host.reserved() >= 3001 * 8);
    }

    #[test]
    fn train_ids_sorted_unique_in_range() {
        let spec = DatasetSpec::unit_test();
        let ids = spec.train_ids();
        assert!(ids.windows(2).all(|w| w[0] < w[1]));
        assert!(ids.iter().all(|&v| v < spec.nodes));
    }

    #[test]
    fn write_and_load_dir_roundtrip() {
        let dir = std::env::temp_dir().join("gnndrive_ds_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::unit_test();
        spec.nodes = 500;
        spec.name = "rt".into();
        Dataset::write_dir(&spec, &dir).unwrap();
        let m = machine();
        let ds = Dataset::load_dir(&dir, &m).unwrap();
        assert_eq!(ds.spec.nodes, 500);
        assert_eq!(ds.labels.len(), 500);
        // File-backed features equal procedural generation.
        let mut got = vec![0u8; 64];
        ds.features.file.backing.read_at(ds.features.row_offset(3), &mut got);
        let mut want = vec![0u8; 64];
        ds.feature_gen.fill_row(3, &mut want);
        assert_eq!(got, want);
        // Topology readable through the storage stack.
        let nbrs = ds.graph.neighbors(&m.storage, 0);
        assert_eq!(nbrs.len() as u64, ds.graph.degree(0));
    }

    #[test]
    fn striped_dir_roundtrip_and_geometry_handshake() {
        let dir = std::env::temp_dir().join("gnndrive_ds_striped_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut spec = DatasetSpec::unit_test();
        spec.nodes = 300;
        spec.name = "rt-striped".into();
        Dataset::write_dir_striped(&spec, &dir, 3, 4096).unwrap();
        for d in 0..3 {
            assert!(dir.join(format!("features.bin.{d}")).exists(), "member {d}");
        }
        assert!(!dir.join("features.bin").exists(), "striped write must not leave a flat file");

        // Matching machine geometry: rows read back byte-identical.
        let m = Machine::new(
            MachineConfig::paper().with_devices(3).with_stripe_bytes(4096),
            Clock::new(0.1),
        );
        let ds = Dataset::load_dir(&dir, &m).unwrap();
        assert_eq!(ds.spec.nodes, 300);
        let mut got = vec![0u8; 64];
        let mut want = vec![0u8; 64];
        // Rows around the 4096-byte chunk boundary (row 64 starts exactly
        // on it) plus the last row.
        for v in [0u64, 63, 64, 65, 299] {
            ds.features.file.backing.read_at(ds.features.row_offset(v), &mut got);
            ds.feature_gen.fill_row(v, &mut want);
            assert_eq!(got, want, "row {v}");
        }

        // Mismatched machine geometry must be refused, loudly.
        let err = Dataset::load_dir(&dir, &machine()).unwrap_err().to_string();
        assert!(err.contains("stripe geometry"), "unexpected error: {err}");
        let m_wrong = Machine::new(
            MachineConfig::paper().with_devices(3).with_stripe_bytes(8192),
            Clock::new(0.1),
        );
        assert!(Dataset::load_dir(&dir, &m_wrong).is_err());
    }
}
