//! Synthetic graph generation: power-law in-degree, community structure,
//! planted label/feature signal.
//!
//! The paper's datasets (Papers100M, Twitter, Friendster, MAG240M) are not
//! shippable; per DESIGN.md §3 we generate analogs with matched *shape*:
//! heavy-tailed in-degree (preferential-attachment-like hubs), community
//! blocks with homophilous edges, and labels correlated with both community
//! and features — so sampling workloads stress the same access patterns and
//! GNN training genuinely learns (Fig 14). Everything is seeded and
//! deterministic.

use crate::util::rng::{hash2, Pcg};

/// Generation parameters (see [`super::dataset::DatasetSpec`] for the
/// registry of paper analogs).
#[derive(Clone, Debug)]
pub struct GraphGenSpec {
    pub nodes: u32,
    pub avg_degree: f64,
    /// Pareto shape for the in-degree tail (smaller = heavier tail).
    pub degree_alpha: f64,
    pub classes: usize,
    /// Nodes per community block.
    pub community_size: u32,
    /// Probability that an edge stays within the community.
    pub homophily: f64,
    pub seed: u64,
}

/// CSC topology + labels.
pub struct GeneratedGraph {
    /// `indptr[v]..indptr[v+1]` indexes `indices` with v's in-neighbors.
    pub indptr: Vec<u64>,
    pub indices: Vec<u32>,
    pub labels: Vec<u16>,
}

/// Bounded Pareto sample with mean ≈ 1 (scale by `avg_degree` at use site).
fn pareto_unit(rng: &mut Pcg, alpha: f64, cap: f64) -> f64 {
    // Pareto(xm=1, alpha) has mean alpha/(alpha-1); divide it out.
    let mean = alpha / (alpha - 1.0);
    let u = (1.0 - rng.f64()).max(1e-12);
    (u.powf(-1.0 / alpha) / mean).min(cap)
}

pub fn generate(spec: &GraphGenSpec) -> GeneratedGraph {
    assert!(spec.nodes > 0 && spec.avg_degree >= 1.0 && spec.degree_alpha > 1.0);
    let n = spec.nodes as usize;
    let mut rng = Pcg::with_stream(spec.seed, 0xDE6);

    // In-degree sequence: heavy-tailed around avg_degree, min 1, with a
    // *hubness* factor correlated with node id. Out-edges below are drawn
    // Zipf-toward-low-ids, so low-id nodes are out-hubs; real graphs
    // (papers, social networks) have correlated in/out degree, and systems
    // like Ginex exploit exactly that correlation when ranking their
    // neighbor caches by degree.
    const HUB_EXP: f64 = 0.35;
    let hub_norm = {
        let mut sum = 0.0;
        for v in 0..n {
            sum += (v as f64 + 1.0).powf(-HUB_EXP);
        }
        sum / n as f64
    };
    let mut degrees = Vec::with_capacity(n);
    let mut total: u64 = 0;
    for v in 0..n {
        let hub = (v as f64 + 1.0).powf(-HUB_EXP) / hub_norm;
        let d = (spec.avg_degree * hub * pareto_unit(&mut rng, spec.degree_alpha, 200.0))
            .round()
            .max(1.0) as u32;
        degrees.push(d);
        total += d as u64;
    }

    let mut indptr = Vec::with_capacity(n + 1);
    indptr.push(0u64);
    let mut indices = Vec::with_capacity(total as usize);
    let comm = spec.community_size.max(1);
    let n_comms = (spec.nodes + comm - 1) / comm;

    for v in 0..spec.nodes {
        let deg = degrees[v as usize];
        let block = v / comm;
        let block_start = block * comm;
        let block_len = comm.min(spec.nodes - block_start);
        for _ in 0..deg {
            let src = if rng.f64() < spec.homophily {
                // Intra-community edge.
                block_start + rng.below(block_len)
            } else {
                // Global edge with hub preference: Zipf over node ids, so
                // low-id nodes become hubs (papers/twitter-like skew).
                rng.zipf(n, 0.9) as u32
            };
            indices.push(src);
        }
        indptr.push(indices.len() as u64);
    }

    // Labels: community-determined with noise. Every community maps to a
    // class; 10% of nodes get a uniformly random class instead.
    let mut labels = Vec::with_capacity(n);
    let mut lrng = Pcg::with_stream(spec.seed, 0x1AB);
    for v in 0..spec.nodes {
        let block = v / comm;
        let label = if lrng.f64() < 0.9 {
            (hash2(spec.seed ^ 0xC1A55, block as u64) % spec.classes as u64) as u16
        } else {
            lrng.below(spec.classes as u32) as u16
        };
        labels.push(label);
        let _ = n_comms;
    }

    GeneratedGraph { indptr, indices, labels }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> GraphGenSpec {
        GraphGenSpec {
            nodes: 5000,
            avg_degree: 12.0,
            degree_alpha: 2.1,
            classes: 8,
            community_size: 100,
            homophily: 0.6,
            seed: 42,
        }
    }

    #[test]
    fn shape_is_valid_csc() {
        let g = generate(&small_spec());
        assert_eq!(g.indptr.len(), 5001);
        assert_eq!(g.labels.len(), 5000);
        assert_eq!(*g.indptr.last().unwrap() as usize, g.indices.len());
        // Monotone indptr.
        for w in g.indptr.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // All indices in range.
        assert!(g.indices.iter().all(|&s| s < 5000));
    }

    #[test]
    fn average_degree_near_target() {
        let g = generate(&small_spec());
        let avg = g.indices.len() as f64 / 5000.0;
        assert!((avg - 12.0).abs() < 2.5, "avg={avg}");
    }

    #[test]
    fn degree_distribution_has_heavy_tail() {
        let g = generate(&small_spec());
        let mut degs: Vec<u64> =
            g.indptr.windows(2).map(|w| w[1] - w[0]).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // Top node should have several times the average degree.
        assert!(degs[0] > 40, "max degree {}", degs[0]);
        // ...and hubs should also exist on the *out* side: low ids appear
        // often as sources thanks to the Zipf global edges.
        let low_id_hits = g.indices.iter().filter(|&&s| s < 50).count();
        assert!(
            low_id_hits as f64 > g.indices.len() as f64 * 0.02,
            "low_id_hits={low_id_hits}"
        );
    }

    #[test]
    fn homophily_holds() {
        let spec = small_spec();
        let g = generate(&spec);
        let mut intra = 0usize;
        for v in 0..spec.nodes {
            let (a, b) = (g.indptr[v as usize] as usize, g.indptr[v as usize + 1] as usize);
            for &src in &g.indices[a..b] {
                if src / spec.community_size == v / spec.community_size {
                    intra += 1;
                }
            }
        }
        let frac = intra as f64 / g.indices.len() as f64;
        assert!(frac > 0.5 && frac < 0.75, "intra frac={frac}");
    }

    #[test]
    fn labels_correlate_with_community_and_cover_classes() {
        let spec = small_spec();
        let g = generate(&spec);
        // Within one community, the majority label dominates.
        let block = &g.labels[0..100];
        let mut counts = [0u32; 8];
        for &l in block {
            counts[l as usize] += 1;
        }
        assert!(*counts.iter().max().unwrap() >= 80);
        // Across the graph all classes appear.
        let mut seen = [false; 8];
        for &l in &g.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn deterministic() {
        let a = generate(&small_spec());
        let b = generate(&small_spec());
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.labels, b.labels);
        let mut spec2 = small_spec();
        spec2.seed = 43;
        let c = generate(&spec2);
        assert_ne!(a.indices, c.indices);
    }
}
