//! Token bucket — the building block for device bandwidth and IOPS limits.
//!
//! Callers `acquire(n)` tokens and block until the bucket can supply them.
//! Refill happens lazily on access at `rate` tokens per *simulated* second
//! (the bucket owns a [`Clock`] so `time_scale` applies uniformly). A bounded
//! `burst` keeps idle periods from banking unbounded credit, which is what
//! gives the saturation knee in the fio-style curves (Fig B.1).

use super::clock::Clock;
use std::sync::Mutex;
use std::time::Duration;

/// Debt-sleep token bucket: `acquire(n)` debits the (shared) balance
/// immediately — it may go negative — and then sleeps off the *caller's own
/// share of the debt* outside the lock. Waits therefore overlap across
/// threads (no per-token condvar handoffs, which on a single-core host cost
/// more than the simulated interval itself), while the k-th acquisition
/// still cannot complete before `(k·n − burst)/rate` — exactly the
/// token-bucket envelope.
#[derive(Debug)]
pub struct TokenBucket {
    clock: Clock,
    rate: f64,  // tokens per simulated second
    burst: f64, // max banked tokens
    state: Mutex<State>,
}

#[derive(Debug)]
struct State {
    tokens: f64,
    last: Duration, // sim time of last refill
}

impl TokenBucket {
    pub fn new(clock: Clock, rate: f64, burst: f64) -> Self {
        assert!(rate > 0.0 && burst > 0.0);
        let now = clock.now();
        TokenBucket {
            clock,
            rate,
            burst,
            state: Mutex::new(State { tokens: burst, last: now }),
        }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    fn refill(&self, st: &mut State) {
        let now = self.clock.now();
        let dt = now.saturating_sub(st.last).as_secs_f64();
        if dt > 0.0 {
            st.tokens = (st.tokens + dt * self.rate).min(self.burst);
            st.last = now;
        }
    }

    /// Acquire `n` tokens; returns after the simulated time at which the
    /// tokens are genuinely available. `n` may exceed `burst` (a large
    /// request occupies the device for its full duration).
    pub fn acquire(&self, n: f64) {
        let debt = {
            let mut st = self.state.lock().unwrap();
            self.refill(&mut st);
            st.tokens -= n;
            // This caller waits until the balance it observes recovers to
            // the level before its own debit (i.e. it pays for the deficit
            // that exists *including* its own debit).
            (-st.tokens).max(0.0)
        };
        if debt > 0.0 {
            self.clock.sleep(Duration::from_secs_f64(debt / self.rate));
        }
    }

    /// Non-blocking probe (used by tests and by best-effort paths).
    pub fn try_acquire(&self, n: f64) -> bool {
        let mut st = self.state.lock().unwrap();
        self.refill(&mut st);
        if st.tokens >= n {
            st.tokens -= n;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn rate_limits_aggregate_throughput() {
        // 10_000 tokens/s, tiny burst: 40 acquisitions of 50 tokens = 2000
        // tokens ≈ 0.2 s minimum (minus the initial burst credit).
        let clock = Clock::new(1.0);
        let tb = TokenBucket::new(clock, 10_000.0, 100.0);
        let t0 = Instant::now();
        for _ in 0..40 {
            tb.acquire(50.0);
        }
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.12, "finished too fast: {dt}s");
        assert!(dt < 0.5, "finished too slow: {dt}s");
    }

    #[test]
    fn concurrent_acquirers_share_rate() {
        let clock = Clock::new(1.0);
        let tb = Arc::new(TokenBucket::new(clock, 20_000.0, 200.0));
        let t0 = Instant::now();
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let tb = tb.clone();
                std::thread::spawn(move || {
                    for _ in 0..10 {
                        tb.acquire(100.0);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 4 threads × 10 × 100 = 4000 tokens at 20k/s ≈ 0.2s.
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.1, "dt={dt}");
        assert!(dt < 0.6, "dt={dt}");
    }

    #[test]
    fn oversized_request_amortizes() {
        let clock = Clock::new(1.0);
        let tb = TokenBucket::new(clock, 10_000.0, 10.0);
        let t0 = Instant::now();
        tb.acquire(1_000.0); // first passes immediately (balance goes negative)
        tb.acquire(1.0); // must wait ~0.1s for the balance to recover
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.05, "dt={dt}");
    }

    #[test]
    fn try_acquire_nonblocking() {
        let clock = Clock::new(1.0);
        let tb = TokenBucket::new(clock, 1000.0, 50.0);
        assert!(tb.try_acquire(10.0));
        assert!(!tb.try_acquire(1e9));
    }
}
