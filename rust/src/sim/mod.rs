//! Simulation primitives: scaled clock, token buckets, semaphores/latches.

pub mod bucket;
pub mod clock;
pub mod queue;
pub mod sema;

pub use bucket::TokenBucket;
pub use clock::{Clock, Stopwatch};
pub use queue::BoundedQueue;
pub use sema::{Latch, SemGuard, Semaphore};
