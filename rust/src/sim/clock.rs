//! Simulation clock with optional time scaling.
//!
//! The storage/compute substrate charges *simulated* time by sleeping real
//! threads, so the whole pipeline (queues, backpressure, overlap) behaves
//! exactly as it would against real devices. `time_scale < 1` compresses all
//! charged waits by that factor — every *reported* duration is converted back
//! to simulated time, so results stay in device-time units. CPU-bound work
//! (sampling, bookkeeping) is real and is not scaled; with aggressive scaling
//! this inflates CPU stages relative to I/O, which is why benches default to
//! scale 1.0 (see DESIGN.md §3).

use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Clock {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    start: Instant,
    /// real seconds per simulated second (≤ 1 compresses waits).
    scale: f64,
}

impl Clock {
    pub fn new(scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "time_scale must be in (0, 1]");
        Clock { inner: Arc::new(Inner { start: Instant::now(), scale }) }
    }

    /// Honor `GNNDRIVE_TIME_SCALE` if set; default 1.0 (honest real time).
    pub fn from_env() -> Self {
        let scale = std::env::var("GNNDRIVE_TIME_SCALE")
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .filter(|s| *s > 0.0 && *s <= 1.0)
            .unwrap_or(1.0);
        Clock::new(scale)
    }

    pub fn scale(&self) -> f64 {
        self.inner.scale
    }

    /// Simulated time elapsed since clock creation.
    pub fn now(&self) -> Duration {
        self.inner.start.elapsed().div_f64(self.inner.scale)
    }

    /// Convert a real elapsed duration into simulated units.
    pub fn to_sim(&self, real: Duration) -> Duration {
        real.div_f64(self.inner.scale)
    }

    /// Convert a simulated duration into the real wait to charge.
    pub fn to_real(&self, sim: Duration) -> Duration {
        sim.mul_f64(self.inner.scale)
    }

    /// Block the calling thread for `sim` simulated time.
    ///
    /// OS sleeps overshoot (timer slack + scheduler latency, ~30 µs on this
    /// box even with `PR_SET_TIMERSLACK=1`), which would systematically
    /// inflate microsecond-scale device latencies. Two corrections keep the
    /// aggregate honest: a calibrated fixed overhead is subtracted from each
    /// sleep, and sleeps shorter than the overhead are *accrued as debt* on
    /// the calling thread and slept off in batches — so high-frequency tiny
    /// charges cost the right total time without per-call overshoot.
    pub fn sleep(&self, sim: Duration) {
        let real = self.to_real(sim);
        if real.is_zero() {
            return;
        }
        tight_timerslack();
        let oh = sleep_overhead();
        DEBT.with(|debt| {
            let owed = debt.get() + real;
            if owed > oh + Duration::from_micros(20) {
                std::thread::sleep(owed - oh);
                debt.set(Duration::ZERO);
            } else {
                debt.set(owed);
            }
        });
    }
}

thread_local! {
    /// Un-slept simulated-time debt for this thread (see [`Clock::sleep`]).
    static DEBT: std::cell::Cell<Duration> = const { std::cell::Cell::new(Duration::ZERO) };
    static SLACK_SET: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Request 1 ns of timer slack for this thread (Linux default is 50 µs,
/// which would dominate 90 µs device latencies).
fn tight_timerslack() {
    SLACK_SET.with(|s| {
        if !s.get() {
            unsafe {
                libc::prctl(libc::PR_SET_TIMERSLACK, 1usize);
            }
            s.set(true);
        }
    });
}

/// One-time calibration of the fixed sleep overshoot on this machine.
fn sleep_overhead() -> Duration {
    use std::sync::OnceLock;
    static OVERHEAD: OnceLock<Duration> = OnceLock::new();
    *OVERHEAD.get_or_init(|| {
        tight_timerslack();
        let target = Duration::from_micros(5);
        let n = 40;
        let t0 = Instant::now();
        for _ in 0..n {
            std::thread::sleep(target);
        }
        let per = t0.elapsed() / n;
        per.saturating_sub(target).clamp(Duration::from_micros(5), Duration::from_micros(120))
    })
}

/// Stopwatch measuring in simulated units.
pub struct Stopwatch<'a> {
    clock: &'a Clock,
    start: Instant,
}

impl<'a> Stopwatch<'a> {
    pub fn start(clock: &'a Clock) -> Self {
        Stopwatch { clock, start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.clock.to_sim(self.start.elapsed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_sleep_compresses_real_time() {
        let clock = Clock::new(0.1);
        let t0 = Instant::now();
        clock.sleep(Duration::from_millis(100)); // should take ~10ms real
        let real = t0.elapsed();
        assert!(real < Duration::from_millis(60), "real={real:?}");
        assert!(real >= Duration::from_millis(9), "real={real:?}");
    }

    #[test]
    fn now_reports_sim_units() {
        let clock = Clock::new(0.5);
        std::thread::sleep(Duration::from_millis(20));
        let sim = clock.now();
        assert!(sim >= Duration::from_millis(35), "sim={sim:?}");
    }

    #[test]
    fn stopwatch_matches_clock() {
        let clock = Clock::new(1.0);
        let sw = Stopwatch::start(&clock);
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed() >= Duration::from_millis(4));
    }
}
