//! Counting semaphore and completion latch (std has no semaphore; the
//! offline build has no tokio). Used for device queue-depth limits and for
//! joining asynchronous I/O batches.

use std::sync::{Condvar, Mutex};

/// Counting semaphore with FIFO-ish wakeup.
#[derive(Debug)]
pub struct Semaphore {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        Semaphore { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    pub fn acquire(&self) {
        let mut p = self.permits.lock().unwrap();
        while *p == 0 {
            p = self.cv.wait(p).unwrap();
        }
        *p -= 1;
    }

    pub fn try_acquire(&self) -> bool {
        let mut p = self.permits.lock().unwrap();
        if *p > 0 {
            *p -= 1;
            true
        } else {
            false
        }
    }

    pub fn release(&self) {
        let mut p = self.permits.lock().unwrap();
        *p += 1;
        drop(p);
        self.cv.notify_one();
    }

    /// RAII guard.
    pub fn guard(&self) -> SemGuard<'_> {
        self.acquire();
        SemGuard { sem: self }
    }

    pub fn available(&self) -> usize {
        *self.permits.lock().unwrap()
    }
}

pub struct SemGuard<'a> {
    sem: &'a Semaphore,
}

impl Drop for SemGuard<'_> {
    fn drop(&mut self) {
        self.sem.release();
    }
}

/// Countdown latch: `wait()` blocks until `count_down()` has been called the
/// configured number of times. Used to join a batch of async completions.
#[derive(Debug)]
pub struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    pub fn new(count: usize) -> Self {
        Latch { remaining: Mutex::new(count), cv: Condvar::new() }
    }

    pub fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        assert!(*r > 0, "latch over-released");
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    pub fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }

    pub fn remaining(&self) -> usize {
        *self.remaining.lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn semaphore_bounds_concurrency() {
        let sem = Arc::new(Semaphore::new(3));
        let peak = Arc::new(AtomicUsize::new(0));
        let cur = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..16)
            .map(|_| {
                let (sem, peak, cur) = (sem.clone(), peak.clone(), cur.clone());
                std::thread::spawn(move || {
                    let _g = sem.guard();
                    let c = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(c, Ordering::SeqCst);
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    cur.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 3);
        assert_eq!(sem.available(), 3);
    }

    #[test]
    fn latch_joins() {
        let latch = Arc::new(Latch::new(4));
        for _ in 0..4 {
            let l = latch.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                l.count_down();
            });
        }
        latch.wait();
        assert_eq!(latch.remaining(), 0);
    }
}
