//! Bounded MPMC queue with close semantics (no crossbeam-channel offline).
//!
//! This is the backbone of both the uring-style I/O rings and the paper's
//! three pipeline queues (extracting / training / releasing, Fig 4): pushes
//! block when full (backpressure — "samplers and extractors would be blocked
//! if corresponding queues are full", §5), pops block when empty, and
//! `close()` drains remaining items then reports disconnection.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug)]
pub struct BoundedQueue<T> {
    state: Mutex<QState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

#[derive(Debug)]
struct QState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Result of a pop on a closed, drained queue.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

/// Error of a batch push interrupted by `close()`: `pushed` items made it
/// into the queue (consumers will still drain them), the rest were dropped.
#[derive(Debug, PartialEq, Eq)]
pub struct PartiallyPushed {
    pub pushed: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            state: Mutex::new(QState { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking push; returns Err if the queue is closed.
    pub fn push(&self, item: T) -> Result<(), Closed> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(Closed);
            }
            if st.items.len() < self.cap {
                st.items.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return Ok(());
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.cap {
            return Err(item);
        }
        st.items.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; returns Err only when closed *and* drained.
    pub fn pop(&self) -> Result<T, Closed> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Ok(item);
            }
            if st.closed {
                return Err(Closed);
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Push a whole batch, blocking as needed; one lock + one wakeup per
    /// burst of space instead of per item.
    ///
    /// An empty batch is a no-op and returns `Ok` immediately — even when
    /// the queue is full (it used to block) or closed (there is nothing to
    /// reject). If the queue closes mid-batch, the error reports how many
    /// items *were* enqueued before the closure (those will still be
    /// drained by consumers), so callers can unwind per-item accounting.
    pub fn push_all(&self, items: Vec<T>) -> Result<(), PartiallyPushed> {
        if items.is_empty() {
            return Ok(());
        }
        let mut pushed_total = 0usize;
        let mut iter = items.into_iter();
        let mut st = self.state.lock().unwrap();
        loop {
            if st.closed {
                return Err(PartiallyPushed { pushed: pushed_total });
            }
            let mut pushed = false;
            while st.items.len() < self.cap {
                match iter.next() {
                    Some(item) => {
                        st.items.push_back(item);
                        pushed = true;
                        pushed_total += 1;
                    }
                    None => {
                        drop(st);
                        self.not_empty.notify_all();
                        return Ok(());
                    }
                }
            }
            if pushed {
                self.not_empty.notify_all();
            }
            st = self.not_full.wait(st).unwrap();
        }
    }

    /// Pop 1..=max items: blocks for the first, then drains up to `max - 1`
    /// more that are already queued (batch consumers amortize wakeups).
    pub fn pop_many(&self, max: usize) -> Result<Vec<T>, Closed> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.items.is_empty() {
                let take = st.items.len().min(max.max(1));
                let out: Vec<T> = st.items.drain(..take).collect();
                drop(st);
                self.not_full.notify_all();
                return Ok(out);
            }
            if st.closed {
                return Err(Closed);
            }
            st = self.not_empty.wait(st).unwrap();
        }
    }

    /// Pop with a deadline: blocks up to `timeout` for an item, then returns
    /// `Ok(None)`. `Err(Closed)` only when closed *and* drained — a closed
    /// queue still hands out its remaining items first, like `pop`. Used by
    /// the serving micro-batcher, whose linger bound (`--serve-wait`) must
    /// flush a partial batch instead of waiting for it to fill.
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<T>, Closed> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Ok(Some(item));
            }
            if st.closed {
                return Err(Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            st = self.not_empty.wait_timeout(st, deadline - now).unwrap().0;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            drop(st);
            self.not_full.notify_one();
        }
        item
    }

    /// Close: waiting producers fail, consumers drain what remains.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.pop().unwrap(), 1);
        assert_eq!(q.pop().unwrap(), 2);
    }

    #[test]
    fn backpressure_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0u32).unwrap();
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push(1).unwrap());
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.len(), 1); // producer is blocked
        assert_eq!(q.pop().unwrap(), 0);
        h.join().unwrap();
        assert_eq!(q.pop().unwrap(), 1);
    }

    #[test]
    fn close_drains_then_disconnects() {
        let q = BoundedQueue::new(4);
        q.push('a').unwrap();
        q.close();
        assert!(q.push('b').is_err());
        assert_eq!(q.pop().unwrap(), 'a');
        assert!(q.pop().is_err());
    }

    #[test]
    fn mpmc_sums_match() {
        let q = Arc::new(BoundedQueue::new(8));
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..100u64 {
                        q.push(p * 1000 + i).unwrap();
                    }
                })
            })
            .collect();
        let consumers: Vec<_> = (0..3)
            .map(|_| {
                let q = q.clone();
                std::thread::spawn(move || {
                    let mut sum = 0u64;
                    while let Ok(v) = q.pop() {
                        sum += v;
                    }
                    sum
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let got: u64 = consumers.into_iter().map(|c| c.join().unwrap()).sum();
        let want: u64 = (0..4).map(|p| (0..100).map(|i| p * 1000 + i).sum::<u64>()).sum();
        assert_eq!(got, want);
    }

    #[test]
    fn push_all_of_empty_batch_returns_immediately_even_when_full() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        q.push(7).unwrap();
        // Regression: this used to block until a consumer made space.
        assert_eq!(q.push_all(Vec::new()), Ok(()));
        assert_eq!(q.len(), 1);
        // Empty batch on a closed queue: nothing to reject.
        q.close();
        assert_eq!(q.push_all(Vec::new()), Ok(()));
        assert_eq!(q.pop().unwrap(), 7);
    }

    #[test]
    fn push_all_blocks_then_completes() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push_all((0..6u32).collect()));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.len(), 2, "producer blocked with queue full");
        let mut got = Vec::new();
        for _ in 0..6 {
            got.push(q.pop().unwrap());
        }
        assert_eq!(h.join().unwrap(), Ok(()));
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn push_all_reports_partial_progress_on_close() {
        let q = Arc::new(BoundedQueue::new(2));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.push_all((0..5u32).collect()));
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(q.len(), 2, "two items fit before the batch blocked");
        q.close();
        let err = h.join().unwrap().unwrap_err();
        assert_eq!(err.pushed, 2, "the already-enqueued prefix is reported");
        // The enqueued prefix still drains after close.
        assert_eq!(q.pop().unwrap(), 0);
        assert_eq!(q.pop().unwrap(), 1);
        assert!(q.pop().is_err());
    }

    #[test]
    fn pop_timeout_times_out_then_delivers() {
        let q = Arc::new(BoundedQueue::new(4));
        // Empty queue: the deadline elapses with Ok(None).
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(5)).unwrap(), None::<u32>);
        assert!(t0.elapsed() >= Duration::from_millis(4));
        // An item arriving before the deadline is delivered promptly.
        let q2 = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            q2.push(9u32).unwrap();
        });
        assert_eq!(q.pop_timeout(Duration::from_secs(5)).unwrap(), Some(9));
        h.join().unwrap();
        // Closed + drained reports Closed, but remaining items drain first.
        q.push(1).unwrap();
        q.close();
        assert_eq!(q.pop_timeout(Duration::from_millis(1)).unwrap(), Some(1));
        assert_eq!(q.pop_timeout(Duration::from_millis(1)), Err(Closed));
    }

    #[test]
    fn try_ops() {
        let q = BoundedQueue::new(1);
        assert!(q.try_pop().is_none());
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_err());
        assert_eq!(q.try_pop(), Some(1));
    }
}
