//! `gnndrive` — CLI launcher for the GNNDrive reproduction.
//!
//! Subcommands:
//!   gen-data   materialize a dataset to a real on-disk directory
//!   table1     print the dataset summary (paper Table 1)
//!   train      run epochs of one system on one dataset (sim or PJRT)
//!   pack       pre-sample the epoch schedule offline and rewrite an
//!              on-disk dataset into a packed per-batch layout (hot.bin +
//!              sequential per-batch packs); `train --packed` then serves
//!              covered batches with ~one large request per device
//!   serve      multi-tenant online-inference frontend over the same stack
//!   figure     regenerate a paper figure/table (2,3,8,9,10,11,12,13,14,tab2,b1)
//!   iostat     fio-style sync/async I/O study on the SSD model (Fig B.1)
//!
//! Packed layout workflow (`pack` → `train --packed`):
//!   gnndrive gen-data --dataset papers-tiny --out d
//!   gnndrive pack --data d --pack-epochs 2 --pack-hot-thresh 2 \
//!       --batch-size 1000 --fanouts 10,10,10 --seed 17
//!   gnndrive train --backend os --data d --packed --epochs 2 \
//!       --batch-size 1000 --fanouts 10,10,10 --seed 17
//! The pack records its schedule (seed/batch-size/fanouts) and stripe
//! geometry in `meta.toml`; `train --packed` refuses a mismatched schedule
//! or geometry, and batches beyond the packed range fall back to the online
//! extraction path unchanged.
//!
//! The I/O stack is pluggable (`--backend`):
//!   sim    simulated SSD + page cache (default; the paper's timing model)
//!   os     real OS files via a pread worker pool — requires an on-disk
//!          dataset, e.g. `gnndrive gen-data --out d &&
//!          gnndrive train --backend os --data d`
//!   uring  real OS files via raw io_uring syscalls (registered files +
//!          buffers, true kernel async). Runtime-probed: on kernels without
//!          io_uring it warns once and falls back to the `os` pread stack.
//!          `gnndrive uring-probe` reports availability (exit 0/1).
//!          Incompatible with `--sync-extract` (rejected at parse time).
//!
//! Unless `--coalesce-bytes`/`--coalesce-gap` are passed explicitly, an
//! adaptive governor retunes the *effective* per-device coalescing config
//! once per epoch from charged IOPS/bandwidth headroom and engine queue
//! pressure (the `hr%[..]` column on striped runs). Explicit values pin the
//! governor off — the user's setting is the experiment.
//!
//! `--hedge` re-issues straggler extraction segments once their in-flight
//! time exceeds the observed p99 segment latency (`--hedge-us` pins the
//! threshold); whichever copy completes first wins, the loser is discarded
//! in place. The epoch summary appends `hedge Nw/M` when hedges fired.
//!
//! Both backends stripe across `--devices N` physical devices in
//! `--stripe-bytes` RAID-0 chunks: per-device engine queues (the `io_depth`
//! budget applies *per device*), per-device charging (sim: N independent
//! SSD models), and stripe-aware coalescing (segments never straddle
//! devices). `gen-data --devices N` writes `features.bin.0 … .N-1` and
//! records the geometry in `meta.toml`; training must then pass matching
//! `--devices/--stripe-bytes`. `--io-workers` sizes the OS backend's pread
//! pool, split round-robin across devices.
//!
//! Feature extraction coalesces per-row reads into multi-row segments
//! (`--coalesce-bytes`, max segment span; `--coalesce-gap`, strict bound on
//! the byte gap bridged between merged rows). `--coalesce-bytes 0` restores
//! one request per row for ablation parity with the paper; the epoch
//! summary's `reqs` / `align+` columns show the coalescing effect.
//!
//! `serve` runs the long-lived serving frontend: `--tenants` request
//! streams hit a *bounded admission queue* (`--admit-cap`; open-loop
//! arrivals at `--rps` are shed, never queued, past the bound — closed-loop
//! `--clients` callers block instead), a micro-batcher groups admitted
//! requests into inference batches (`--serve-batch` size bound,
//! `--serve-wait` linger bound), and `--serve-workers` workers drive each
//! batch through sampling, coalesced feature extraction and a read-only
//! forward pass. All tenants share one feature buffer (hot nodes extracted
//! for one tenant are buffer hits for the rest); `--per-tenant-buffer`
//! ablates that into private per-tenant buffers, and `--serve-while-train`
//! runs a concurrent training loop over the shared buffer. Per-stage
//! p50/p95/p99 (admission/sample/extract/compute) are reported per epoch
//! and merged into a final summary.
//!
//! Tiered feature placement (`--tier`): `--tier gpu --gpu-mem <bytes>`
//! layers a simulated-GPU-resident hot tier above the host feature buffer
//! for `train` and `serve` — frequency/degree-weighted promotion on
//! repeated host hits, batched background demotion, admission bypass for
//! one-off cold seeds, host→device transfers charged through the PCIe
//! model. `--tier host` (the default) is byte- and charge-identical to the
//! pre-tier single-buffer stack. `--gpu-oversub` is the UVM
//! oversubscription ablation: the tier admits past capacity and pays a
//! modeled fault-migration transfer per over-capacity access instead of
//! demoting. The epoch/run summary appends `tier gpu …` counters.
//!
//! Fault tolerance: `--fault-rate/--fault-short/--fault-stall/
//! --fault-bad-range` wrap the selected backend in deterministic seeded
//! fault injection (`--fault-seed`); engines retry per `--io-retries`, and
//! `--on-io-error {fail,retry,drop-rows}` picks the batch-level policy when
//! retries are exhausted (serving always degrades to per-request error
//! responses instead). On a striped array `--fault-device i` confines the
//! storm to the stripe member `i` (a single-device brownout).

use gnndrive::baselines::{build_system, sim_trainer, SystemKind};
use gnndrive::config::{FaultProfile, Machine, MachineConfig, OnIoError, TrainConfig};
use gnndrive::extract::CoalesceConfig;
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::layout::PackedLayout;
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::serve::{BatchSpec, ServeConfig, ServeEngine, ServeReport};
use gnndrive::sim::Clock;
use gnndrive::storage::{BackendKind, FaultPlan, IoBackend as _, RetryPolicy};
use gnndrive::tier::TierKind;
use gnndrive::util::args::Args;
use std::sync::Arc;

fn main() {
    let args = Args::new(
        "gnndrive — disk-based GNN training (ICPP '24 reproduction)\n\n\
         USAGE: gnndrive <gen-data|table1|train|pack|serve|figure|iostat|uring-probe> [options]",
    )
    .opt("dataset", "papers100m-mini", "dataset name (see table1)")
    .opt("system", "gnndrive", "gnndrive|gnndrive-cpu|pyg+|ginex|marius (case-insensitive)")
    .opt("model", "graphsage", "graphsage|gcn|gat")
    .opt(
        "backend",
        "sim",
        "I/O backend: sim (simulated SSD) | os (real files via pread) | uring \
         (real files via io_uring; probes at startup, falls back to os)",
    )
    .opt("data", "", "on-disk dataset dir (gen-data output); required for --backend os/uring")
    .opt(
        "devices",
        "1",
        "stripe the storage stack across N devices; engine io-depth and sim SSD \
         IOPS/queue-depth ceilings apply PER DEVICE",
    )
    .opt("stripe-bytes", "1MiB", "RAID-0 chunk size of the stripe (ignored at --devices 1)")
    .opt(
        "io-workers",
        "8",
        "os backend: pread-pool threads, bound round-robin to stripe devices",
    )
    .opt(
        "io-depth",
        "128",
        "async engine submission-queue depth per extractor (applies PER DEVICE on a stripe)",
    )
    .opt(
        "coalesce-bytes",
        "256KiB",
        "max span of one coalesced feature-read segment; 0 = one request per row (ablation)",
    )
    .opt(
        "coalesce-gap",
        "16KiB",
        "max byte gap bridged when merging feature rows into a segment (strict bound)",
    )
    .opt("epochs", "1", "epochs to run")
    .opt("batches", "", "mini-batches per epoch (default: full epoch)")
    .opt("batch-size", "1000", "mini-batch size")
    .opt("fanouts", "10,10,10", "comma-separated neighbor fanouts")
    .opt("seed", "17", "shuffle/sampling seed (must match between pack and train --packed)")
    .opt("pack-epochs", "1", "pack: epochs of the schedule to pre-sample and pack")
    .opt(
        "pack-hot-thresh",
        "2",
        "pack: rows appearing in at least this many batches go to the hot tier (hot.bin)",
    )
    .opt("memory-gb", "32", "host memory in paper-scale GB (divided by 256)")
    .opt("dim", "", "feature dimension override")
    .opt("out", "data/papers-tiny", "output directory for gen-data")
    .opt("tenants", "4", "serve: independent request streams sharing the node popularity")
    .opt("requests", "2000", "serve: total inference requests per epoch")
    .opt("rps", "0", "serve: open-loop Poisson arrival rate (req/s, sim time); 0 = closed loop")
    .opt("clients", "8", "serve: closed-loop callers, one outstanding request each")
    .opt("admit-cap", "256", "serve: admission-queue bound; open-loop offers past it are SHED")
    .opt("serve-batch", "32", "serve: max requests per inference micro-batch")
    .opt("serve-wait", "2ms", "serve: max linger before a partial micro-batch flushes")
    .opt("serve-workers", "2", "serve: serving worker threads")
    .opt(
        "serve-buffer-mult",
        "4",
        "serve: feature-buffer slots as a multiple of the (workers+1)×cap floor",
    )
    .opt(
        "hot-nodes",
        "0",
        "serve: size of the popular-seed head requests concentrate on (0 = whole graph)",
    )
    .opt(
        "tier",
        "host",
        "feature placement: host (single host buffer, the pre-tier path) | gpu \
         (GPU-resident hot tier above it; requires --gpu-mem)",
    )
    .opt(
        "gpu-mem",
        "",
        "GPU hot-tier capacity in bytes (accepts KiB/MiB/GiB); required with --tier gpu",
    )
    .flag(
        "gpu-oversub",
        "tier ablation: UVM-style oversubscription — admit past --gpu-mem and pay a \
         modeled fault migration per over-capacity access (requires --tier gpu)",
    )
    .opt("fault-seed", "1024023", "fault injection: root seed of the deterministic fault plan")
    .opt("fault-rate", "0", "fault injection: transient-error probability per read try")
    .opt("fault-short", "0", "fault injection: short-read probability per read try")
    .opt("fault-stall", "0", "fault injection: stall probability per read try")
    .opt("fault-stall-us", "200", "fault injection: stall duration (sim microseconds)")
    .opt(
        "fault-bad-range",
        "",
        "fault injection: permanently unreadable byte range START:LEN (sizes accept KiB/MiB)",
    )
    .opt(
        "fault-device",
        "",
        "fault injection: confine the storm to one stripe member (device index < --devices)",
    )
    .opt("io-retries", "3", "engine retry policy: max re-issues per failed request")
    .opt(
        "on-io-error",
        "fail",
        "train: batch policy once retries are exhausted (fail | retry | drop-rows)",
    )
    .flag(
        "per-tenant-buffer",
        "serve ablation: private per-tenant feature buffers (same slots each) \
         instead of one shared buffer",
    )
    .flag(
        "serve-while-train",
        "serve: run a concurrent training loop sharing the serving feature buffer",
    )
    .flag(
        "packed",
        "train: serve pre-sampled batches from the packed layout in --data \
         (a `gnndrive pack` output); gnndrive system only",
    )
    .flag(
        "sync-extract",
        "train ablation: synchronous extraction (no async I/O overlap); \
         incompatible with --backend uring",
    )
    .flag(
        "hedge",
        "train: hedged reissue of straggler extraction segments past the \
         observed p99 in-flight latency (first copy wins)",
    )
    .opt(
        "hedge-us",
        "",
        "train: pin the hedge threshold to a fixed microsecond count \
         (implies --hedge; default: adaptive p99)",
    )
    .flag("full", "full sweep grids for `figure` (default: quick)")
    .parse();

    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let code = match cmd {
        "gen-data" => cmd_gen_data(&args),
        "table1" => {
            print!("{}", gnndrive::experiments::table1());
            0
        }
        "train" => cmd_train(&args),
        "pack" => cmd_pack(&args),
        "serve" => cmd_serve(&args),
        "figure" => cmd_figure(&args),
        "iostat" => {
            print!("{}", gnndrive::experiments::figb1(!args.has("full")));
            0
        }
        // Machine-readable probe for scripts (`scripts/tier1.sh` downgrades
        // its uring smokes to SKIP on exit 1).
        "uring-probe" => match gnndrive::storage::probe_uring() {
            Ok(()) => {
                println!("io_uring: available");
                0
            }
            Err(e) => {
                println!("io_uring: unavailable ({e})");
                1
            }
        },
        _ => {
            args.print_help();
            if cmd == "help" {
                0
            } else {
                eprintln!(
                    "\nunknown command {cmd:?}; valid commands: \
                     gen-data, table1, train, pack, serve, figure, iostat, uring-probe"
                );
                2
            }
        }
    };
    std::process::exit(code);
}

fn cmd_gen_data(args: &Args) -> i32 {
    let name = if args.get("dataset").is_none() {
        "papers-tiny"
    } else {
        args.get_or_default("dataset")
    };
    let Some(spec) = DatasetSpec::by_name(name) else {
        eprintln!("unknown dataset {name:?}");
        return 2;
    };
    let out = std::path::PathBuf::from(args.get_or_default("out"));
    let devices = args.get_usize("devices").unwrap_or(1).max(1);
    let stripe_bytes = match parse_stripe_bytes(args) {
        Ok(v) => v,
        Err(code) => return code,
    };
    println!("writing {name} to {out:?} …");
    match Dataset::write_dir_striped(&spec, &out, devices, stripe_bytes) {
        Ok(()) => {
            if devices > 1 {
                println!(
                    "done: indptr.bin indices.bin labels.bin features.bin.0…{} meta.toml \
                     ({} devices, {} chunks)",
                    devices - 1,
                    devices,
                    gnndrive::util::units::fmt_bytes(stripe_bytes),
                );
            } else {
                println!("done: indptr.bin indices.bin labels.bin features.bin meta.toml");
            }
            0
        }
        Err(e) => {
            eprintln!("gen-data failed: {e}");
            1
        }
    }
}

fn parse_fanouts(s: &str) -> Vec<usize> {
    s.split(',').filter_map(|p| p.trim().parse().ok()).collect()
}

/// Parse and validate `--stripe-bytes`; `Err` carries the process exit
/// code. Both backends issue sector-granular direct I/O, so a stripe chunk
/// that is not a positive multiple of the sector would split requests at
/// unreadable offsets — reject it at parse time instead.
fn parse_stripe_bytes(args: &Args) -> Result<u64, i32> {
    const SECTOR: u64 = 512; // MachineConfig::paper() sector, both backends
    match gnndrive::util::units::parse_bytes(args.get_or_default("stripe-bytes")) {
        Ok(v) if v > 0 && v % SECTOR == 0 => Ok(v),
        Ok(v) => {
            eprintln!(
                "--stripe-bytes: {} is not a positive multiple of the {}-byte device sector \
                 (try 4KiB, 64KiB, 1MiB, …)",
                gnndrive::util::units::fmt_bytes(v),
                SECTOR,
            );
            Err(2)
        }
        Err(e) => {
            eprintln!("--stripe-bytes: {e}");
            Err(2)
        }
    }
}

/// Parse the `--fault-*` / `--io-retries` flags into a fault profile;
/// `Ok(None)` when no fault knob is active (the backend stays unwrapped).
/// `Err` carries the process exit code.
fn parse_fault(args: &Args) -> Result<Option<FaultProfile>, i32> {
    let rate = |key: &str| -> Result<f64, i32> {
        let v = args.get_f64(key).unwrap_or(0.0);
        if !(0.0..=1.0).contains(&v) {
            eprintln!("--{key}: probability must be in [0, 1], got {v}");
            return Err(2);
        }
        Ok(v)
    };
    let mut plan = FaultPlan {
        seed: args.get_usize("fault-seed").unwrap_or(0xFA017) as u64,
        transient_rate: rate("fault-rate")?,
        short_rate: rate("fault-short")?,
        stall_rate: rate("fault-stall")?,
        stall_us: args.get_usize("fault-stall-us").unwrap_or(200) as u64,
        bad_ranges: Vec::new(),
        device: None,
    };
    if let Some(d) = args.get("fault-device").filter(|s| !s.is_empty()) {
        match d.parse::<usize>() {
            Ok(i) => plan.device = Some(i),
            Err(_) => {
                eprintln!("--fault-device: expected a device index, got {d:?}");
                return Err(2);
            }
        }
    }
    if let Some(spec) = args.get("fault-bad-range").filter(|s| !s.is_empty()) {
        let parts: Vec<&str> = spec.splitn(2, ':').collect();
        let parsed = match parts.as_slice() {
            [start, len] => gnndrive::util::units::parse_bytes(start)
                .and_then(|s| gnndrive::util::units::parse_bytes(len).map(|l| (s, l))),
            _ => Err("expected START:LEN".to_string()),
        };
        match parsed {
            Ok((start, len)) if len > 0 => plan.bad_ranges.push((start, len)),
            Ok(_) => {
                eprintln!("--fault-bad-range: LEN must be > 0");
                return Err(2);
            }
            Err(e) => {
                eprintln!("--fault-bad-range: {e} (format: START:LEN, e.g. 4096:64KiB)");
                return Err(2);
            }
        }
    }
    if !plan.is_active() {
        return Ok(None);
    }
    let policy = RetryPolicy {
        max_retries: args.get_usize("io-retries").unwrap_or(3) as u32,
        ..RetryPolicy::default()
    };
    Ok(Some(FaultProfile { plan, policy }))
}

/// Build the machine and load/materialize the dataset from the shared
/// `--backend/--data/--dataset/--dim/--memory-gb/--fault-*` flags (used by
/// `train` and `serve`). `Err` carries the process exit code.
fn setup_machine_and_dataset(args: &Args) -> Result<(Arc<Machine>, Arc<Dataset>), i32> {
    let backend_name = args.get_or_default("backend");
    let Some(backend) = BackendKind::by_name(backend_name) else {
        eprintln!(
            "unknown backend {backend_name:?}; valid backends: {}",
            BackendKind::names()
        );
        return Err(2);
    };
    let gb: u64 = args.get_usize("memory-gb").unwrap_or(32) as u64;
    let devices = args.get_usize("devices").unwrap_or(1).max(1);
    let stripe_bytes = match parse_stripe_bytes(args) {
        Ok(v) => v,
        Err(code) => return Err(code),
    };
    let io_workers = match parse_positive_count(args, "io-workers", "pread-pool thread count") {
        Ok(v) => v,
        Err(code) => return Err(code),
    };
    let mut mcfg = MachineConfig::paper()
        .with_paper_host_gb(gb)
        .with_backend(backend)
        .with_devices(devices)
        .with_stripe_bytes(stripe_bytes)
        .with_io_workers(io_workers);
    if let Some(profile) = parse_fault(args)? {
        if let Some(d) = profile.plan.device {
            if d >= devices {
                eprintln!("--fault-device {d} out of range for --devices {devices}");
                return Err(2);
            }
        }
        mcfg = mcfg.with_fault(profile);
    }
    let machine = Arc::new(Machine::new(mcfg, Clock::from_env()));

    let data_dir = args.get("data").filter(|d| !d.is_empty());
    if matches!(backend, BackendKind::Os | BackendKind::Uring) && data_dir.is_none() {
        eprintln!(
            "--backend {} reads real files and needs an on-disk dataset:\n  \
             gnndrive gen-data --dataset papers-tiny --out <dir>\n  \
             gnndrive <train|serve> --backend {} --data <dir> …",
            backend.label(),
            backend.label(),
        );
        return Err(2);
    }
    let ds = if let Some(dir) = data_dir {
        match Dataset::load_dir(std::path::Path::new(dir), &machine) {
            Ok(d) => Arc::new(d),
            Err(e) => {
                eprintln!("dataset dir {dir:?}: {e}");
                return Err(1);
            }
        }
    } else {
        let ds_name = args.get_or_default("dataset");
        let Some(mut spec) = DatasetSpec::by_name(ds_name) else {
            eprintln!("unknown dataset {ds_name:?} (see `gnndrive table1` for names)");
            return Err(2);
        };
        if let Some(d) = args.get("dim").and_then(|d| d.parse().ok()) {
            spec = spec.with_dim(d);
        }
        match Dataset::materialize(&spec, &machine) {
            Ok(d) => Arc::new(d),
            Err(e) => {
                eprintln!("dataset: {e}");
                return Err(1);
            }
        }
    };
    Ok((machine, ds))
}

/// Parse `--coalesce-bytes` / `--coalesce-gap` (shared by `train` and
/// `serve`). `Err` carries the process exit code. The max segment span is
/// issued as sector-granular direct I/O, so anything that is neither 0
/// (coalescing off) nor a positive multiple of the sector would split every
/// merge at an unreadable boundary — reject it at parse time, mirroring
/// `--stripe-bytes`.
fn parse_coalesce(args: &Args) -> Result<(usize, usize), i32> {
    const SECTOR: u64 = 512; // MachineConfig::paper() sector, both backends
    let parse_size =
        |key: &str| match gnndrive::util::units::parse_bytes(args.get_or_default(key)) {
            Ok(v) => Ok(v as usize),
            Err(e) => {
                eprintln!("--{key}: {e}");
                Err(2)
            }
        };
    let bytes = parse_size("coalesce-bytes")?;
    if bytes != 0 && (bytes as u64) % SECTOR != 0 {
        eprintln!(
            "--coalesce-bytes: {} is neither 0 (coalescing off) nor a positive multiple \
             of the {}-byte device sector (try 4KiB, 64KiB, 256KiB, …)",
            gnndrive::util::units::fmt_bytes(bytes as u64),
            SECTOR,
        );
        return Err(2);
    }
    Ok((bytes, parse_size("coalesce-gap")?))
}

/// Parse and validate one positive-count engine knob (`--io-depth`,
/// `--io-workers`): a zero queue depth or empty worker pool would deadlock
/// the engine at the first submit, so reject with the expected shape in the
/// message instead. `Err` carries the process exit code.
fn parse_positive_count(args: &Args, key: &str, what: &str) -> Result<usize, i32> {
    match args.get_usize(key) {
        Ok(v) if v > 0 => Ok(v),
        Ok(v) => {
            eprintln!("--{key}: expected a positive {what}, got {v}");
            Err(2)
        }
        Err(e) => {
            eprintln!("{e}");
            Err(2)
        }
    }
}

/// Parse the hedging knobs: `--hedge-us` pins the threshold and implies
/// `--hedge`. Returns `(enabled, pin_us)`; `Err` carries the exit code.
fn parse_hedge(args: &Args) -> Result<(bool, Option<u64>), i32> {
    let pin = match args.get("hedge-us").filter(|s| !s.is_empty()) {
        None => None,
        Some(s) => match s.parse::<u64>() {
            Ok(v) if v > 0 => Some(v),
            _ => {
                eprintln!("--hedge-us: expected a positive microsecond count, got {s:?}");
                return Err(2);
            }
        },
    };
    Ok((args.has("hedge") || pin.is_some(), pin))
}

/// Parse the tiered-placement knobs: `--tier host|gpu`, `--gpu-mem`,
/// `--gpu-oversub`. Returns `(tier, gpu_mem_bytes, oversub)`; `Err` carries
/// the process exit code. A GPU tier with no capacity (or a capacity string
/// that does not parse) cannot place a single row, and oversubscription is
/// an ablation *of* the GPU tier — both are user errors, rejected here with
/// the offending flag named rather than silently ignored downstream.
fn parse_tier(args: &Args) -> Result<(TierKind, u64, bool), i32> {
    let tier_name = args.get_or_default("tier");
    let Some(tier) = TierKind::by_name(tier_name) else {
        eprintln!("unknown --tier {tier_name:?}; valid tiers: {}", TierKind::names());
        return Err(2);
    };
    let gpu_mem = match args.get("gpu-mem").filter(|s| !s.is_empty()) {
        None => 0,
        Some(s) => match gnndrive::util::units::parse_bytes(s) {
            Ok(v) if v > 0 => v,
            Ok(_) => {
                eprintln!("--gpu-mem: expected a positive byte count, got {s:?}");
                return Err(2);
            }
            Err(e) => {
                eprintln!("--gpu-mem: {e} (try 256MiB, 1GiB, …)");
                return Err(2);
            }
        },
    };
    if tier == TierKind::Gpu && gpu_mem == 0 {
        eprintln!(
            "--tier gpu needs a device budget: pass --gpu-mem with a positive \
             byte count (e.g. --tier gpu --gpu-mem 256MiB)"
        );
        return Err(2);
    }
    if args.has("gpu-oversub") && tier != TierKind::Gpu {
        eprintln!(
            "--gpu-oversub is an ablation of the GPU hot tier and requires \
             --tier gpu"
        );
        return Err(2);
    }
    Ok((tier, gpu_mem, args.has("gpu-oversub")))
}

fn cmd_train(args: &Args) -> i32 {
    let system_name = args.get_or_default("system");
    let Some(kind) = SystemKind::by_name(system_name) else {
        eprintln!(
            "unknown system {system_name:?}; valid systems: {}",
            SystemKind::names()
        );
        return 2;
    };
    let model_name = args.get_or_default("model");
    let Some(model) = ModelKind::by_name(model_name) else {
        eprintln!("unknown model {model_name:?}; valid models: graphsage, gcn, gat");
        return 2;
    };
    // Contradictory knob combos are user errors, not silent overrides:
    // uring exists to overlap I/O, `--sync-extract` forbids overlap.
    if BackendKind::by_name(args.get_or_default("backend")) == Some(BackendKind::Uring)
        && args.has("sync-extract")
    {
        eprintln!(
            "--backend uring is an asynchronous engine and cannot run with \
             --sync-extract; drop one of the two (use --backend os for the \
             synchronous ablation)"
        );
        return 2;
    }
    let io_depth = match parse_positive_count(args, "io-depth", "per-device queue depth") {
        Ok(v) => v,
        Err(code) => return code,
    };
    let (hedge, hedge_us) = match parse_hedge(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let (tier, gpu_mem, gpu_oversub) = match parse_tier(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let (machine, ds) = match setup_machine_and_dataset(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let (coalesce_bytes, coalesce_gap) = match parse_coalesce(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let on_io_error_name = args.get_or_default("on-io-error");
    let Some(on_io_error) = OnIoError::by_name(on_io_error_name) else {
        eprintln!(
            "unknown --on-io-error {on_io_error_name:?}; valid policies: {}",
            OnIoError::names()
        );
        return 2;
    };
    let cfg = TrainConfig {
        batch_size: args.get_usize("batch-size").unwrap_or(1000),
        fanouts: parse_fanouts(args.get_or_default("fanouts")),
        batches_per_epoch: args.get("batches").and_then(|b| b.parse().ok()),
        seed: args.get_usize("seed").unwrap_or(17) as u64,
        coalesce_bytes,
        coalesce_gap,
        // Explicit CLI coalesce values pin the adaptive governor off: the
        // user's setting is the experiment.
        coalesce_pinned: args.get("coalesce-bytes").is_some()
            || args.get("coalesce-gap").is_some(),
        io_depth,
        sync_extract: args.has("sync-extract"),
        hedge,
        hedge_us,
        on_io_error,
        tier,
        gpu_mem,
        gpu_oversub,
        ..TrainConfig::default()
    };
    let epochs = args.get_usize("epochs").unwrap_or(1);
    println!(
        "{} on {} ({} nodes, dim {}), {} epochs, machine {} ({} host, backend {})",
        kind.label(),
        ds.spec.name,
        ds.spec.nodes,
        ds.spec.dim,
        epochs,
        machine.cfg.name,
        gnndrive::util::units::fmt_bytes(machine.cfg.host_mem),
        machine.backend.name(),
    );
    if args.has("packed") {
        return cmd_train_packed(args, kind, &machine, &ds, cfg, model, epochs);
    }
    let mut sys = match build_system(kind, &machine, &ds, cfg, model) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{}: {e}", kind.label());
            return 1;
        }
    };
    for e in 0..epochs {
        match sys.run_epoch(e as u64) {
            Ok(st) => println!("epoch {e}: {}", st.summary()),
            Err(err) => {
                eprintln!("epoch {e}: {err}");
                return 1;
            }
        }
    }
    0
}

/// `train --packed`: build the GNNDrive engine directly (the packed layout
/// is a GNNDrive-only mechanism), attach the layout from `--data`, run.
fn cmd_train_packed(
    args: &Args,
    kind: SystemKind,
    machine: &Arc<Machine>,
    ds: &Arc<Dataset>,
    cfg: TrainConfig,
    model: ModelKind,
    epochs: usize,
) -> i32 {
    if kind != SystemKind::GnnDriveGpu {
        eprintln!("--packed is only supported for --system gnndrive");
        return 2;
    }
    let Some(dir) = args.get("data").filter(|d| !d.is_empty()) else {
        eprintln!(
            "--packed serves batches from a packed on-disk layout and needs \
             --data <dir> (a `gnndrive pack` output)"
        );
        return 2;
    };
    let layout = match PackedLayout::load_dir(std::path::Path::new(dir), machine) {
        Ok(l) => Arc::new(l),
        Err(e) => {
            eprintln!("packed layout {dir:?}: {e}");
            return 1;
        }
    };
    let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Gpu, 256);
    let mut engine = match GnnDrive::new(machine, ds, cfg, Variant::Gpu, trainer) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("gnndrive: {e}");
            return 1;
        }
    };
    match engine.attach_layout(layout) {
        Ok(pinned) => println!("packed layout attached: {pinned} hot row(s) pinned"),
        Err(e) => {
            eprintln!("packed layout: {e}");
            return 1;
        }
    }
    for e in 0..epochs {
        match engine.try_run_epoch(e as u64) {
            Ok(st) => println!("epoch {e}: {}", st.summary()),
            Err(err) => {
                eprintln!("epoch {e}: {err}");
                return 1;
            }
        }
    }
    0
}

/// `pack`: pre-sample `--pack-epochs` epochs of the train schedule and
/// rewrite the dataset dir into the packed layout.
fn cmd_pack(args: &Args) -> i32 {
    let Some(dir) = args.get("data").filter(|d| !d.is_empty()) else {
        eprintln!(
            "pack rewrites an on-disk dataset in place and needs --data <dir> \
             (a `gnndrive gen-data` output)"
        );
        return 2;
    };
    let dir = std::path::PathBuf::from(dir);
    let (machine, ds) = match setup_machine_and_dataset(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let cfg = TrainConfig {
        batch_size: args.get_usize("batch-size").unwrap_or(1000),
        fanouts: parse_fanouts(args.get_or_default("fanouts")),
        batches_per_epoch: args.get("batches").and_then(|b| b.parse().ok()),
        seed: args.get_usize("seed").unwrap_or(17) as u64,
        ..TrainConfig::default()
    };
    let schedule = cfg.schedule_spec();
    let epochs = args.get_usize("pack-epochs").unwrap_or(1).max(1) as u64;
    let hot_thresh = args.get_usize("pack-hot-thresh").unwrap_or(2).max(1) as u32;
    println!(
        "packing {dir:?}: {epochs} epoch(s), batch {}, fanouts {:?}, seed {}, hot-thresh {hot_thresh} …",
        schedule.batch_size, schedule.fanouts, schedule.seed,
    );
    match gnndrive::layout::pack_dataset(&machine, &ds, &dir, &schedule, epochs, hot_thresh) {
        Ok(st) => {
            println!(
                "packed: {} epoch(s) × {} batch(es), {} hot row(s), {} cold row(s), \
                 packs {} ({} alignment pad)",
                st.epochs,
                st.batches_per_epoch,
                st.hot_rows,
                st.cold_rows,
                gnndrive::util::units::fmt_bytes(st.pack_bytes),
                gnndrive::util::units::fmt_bytes(st.pad_bytes),
            );
            0
        }
        Err(e) => {
            eprintln!("pack failed: {e}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let model_name = args.get_or_default("model");
    let Some(model) = ModelKind::by_name(model_name) else {
        eprintln!("unknown model {model_name:?}; valid models: graphsage, gcn, gat");
        return 2;
    };
    let (tier, gpu_mem, gpu_oversub) = match parse_tier(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    // The GPU tier sits above the *shared* buffer; per-tenant buffers have
    // no single host tier for it to extend. Reject the combination here, at
    // parse time, rather than deep in engine construction.
    if tier == TierKind::Gpu && args.has("per-tenant-buffer") {
        eprintln!(
            "--tier gpu extends the shared feature buffer and cannot combine \
             with --per-tenant-buffer; drop one of the two"
        );
        return 2;
    }
    let (machine, ds) = match setup_machine_and_dataset(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let (coalesce_bytes, coalesce_gap) = match parse_coalesce(args) {
        Ok(pair) => pair,
        Err(code) => return code,
    };
    let serve_wait = match gnndrive::util::units::parse_duration(args.get_or_default("serve-wait"))
    {
        Ok(v) => v,
        Err(e) => {
            eprintln!("--serve-wait: {e}");
            return 2;
        }
    };
    let rps = args.get_f64("rps").unwrap_or(0.0);
    let cfg = ServeConfig {
        tenants: args.get_usize("tenants").unwrap_or(4).max(1),
        workers: args.get_usize("serve-workers").unwrap_or(2).max(1),
        requests: args.get_usize("requests").unwrap_or(2000) as u64,
        rps,
        clients: args.get_usize("clients").unwrap_or(8).max(1),
        admit_cap: args.get_usize("admit-cap").unwrap_or(256).max(1),
        batch: BatchSpec {
            max_requests: args.get_usize("serve-batch").unwrap_or(32).max(1),
            max_wait: serve_wait,
        },
        fanouts: parse_fanouts(args.get_or_default("fanouts")),
        coalesce: CoalesceConfig { max_bytes: coalesce_bytes, gap_bytes: coalesce_gap },
        buffer_mult: args.get_usize("serve-buffer-mult").unwrap_or(4).max(1),
        per_tenant_buffer: args.has("per-tenant-buffer"),
        serve_while_train: args.has("serve-while-train"),
        hot_nodes: args.get_usize("hot-nodes").unwrap_or(0) as u32,
        model,
        hidden: 256, // paper §5 hidden dimension, same as training
        tier,
        gpu_mem,
        gpu_oversub,
        ..ServeConfig::default()
    };
    let epochs = args.get_usize("epochs").unwrap_or(1).max(1);
    println!(
        "serving {} ({} nodes, dim {}) on backend {}: {} tenants, {} workers, {} × {} requests ({}), admit cap {}, batch ≤{} / {}{}{}",
        ds.spec.name,
        ds.spec.nodes,
        ds.spec.dim,
        machine.backend.name(),
        cfg.tenants,
        cfg.workers,
        epochs,
        cfg.requests,
        if cfg.rps > 0.0 {
            format!("open loop @ {} rps", cfg.rps)
        } else {
            format!("closed loop, {} clients", cfg.clients)
        },
        cfg.admit_cap,
        cfg.batch.max_requests,
        gnndrive::util::units::fmt_dur(cfg.batch.max_wait),
        if cfg.per_tenant_buffer { ", per-tenant buffers" } else { ", shared buffer" },
        if cfg.serve_while_train { ", concurrent trainer" } else { "" },
    );
    let engine = match ServeEngine::new(&machine, &ds, cfg) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("serve: {e}");
            return 1;
        }
    };
    let mut merged = ServeReport::default();
    for e in 0..epochs {
        match engine.run(e as u64) {
            Ok(report) => {
                println!("epoch {e}: {}", report.summary());
                merged.merge(&report);
            }
            Err(err) => {
                eprintln!("epoch {e}: {err}");
                return 1;
            }
        }
    }
    println!("final: {}", merged.summary());
    println!("{}", merged.stage_detail());
    0
}

fn cmd_figure(args: &Args) -> i32 {
    let Some(id) = args.positional.get(1) else {
        eprintln!("usage: gnndrive figure <2|3|8|9|10|11|12|13|14|tab1|tab2|b1> [--full]");
        return 2;
    };
    let quick = !(args.has("full") || gnndrive::experiments::is_full());
    match gnndrive::experiments::run_figure(id, quick) {
        Some(report) => {
            print!("{report}");
            0
        }
        None => {
            eprintln!("unknown figure {id:?}");
            2
        }
    }
}
