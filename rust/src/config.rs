//! Machine & training configuration.
//!
//! A [`MachineConfig`] describes the simulated testbed (SSD model, host /
//! device memory budgets, GPU model, PCIe link); [`Machine`] instantiates
//! the shared substrate every training system runs on. Presets mirror the
//! paper's two testbeds at 1/256 memory scale (DESIGN.md §3). Configs load
//! from TOML-subset files and accept CLI overrides.

use crate::sim::Clock;
use crate::storage::osfile::DEFAULT_POOL_THREADS;
use crate::storage::{
    BackendKind, DeviceMemory, FaultInjectBackend, FaultPlan, HostMemory, IoBackend,
    OsFileBackend, PageCache, Pcie, PcieConfig, RetryPolicy, SsdConfig, SsdSim, Storage,
    StripeSpec,
};
use crate::util::toml::Doc;
use crate::util::units;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// Host-memory scale factor relative to the paper's testbed (32 GB →
/// 128 MiB). Host memory holds graph-proportional state, and the graphs are
/// scaled 1/256.
pub const MEM_SCALE: u64 = 256;

/// Device-memory scale factor (24 GB → 768 MiB). Device memory holds
/// *per-batch* state (the feature buffer), and the mini-batch size is NOT
/// scaled (paper's 1000), so the device budget scales far less aggressively
/// — in the paper the GPU was never the binding constraint for the dim
/// sweeps, and this preserves that.
pub const DEV_MEM_SCALE: u64 = 32;

/// Which accelerator the train stage runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GpuModel {
    /// NVIDIA GeForce RTX 3090 (the paper's main testbed).
    Rtx3090,
    /// NVIDIA Tesla K80 (the Fig 13 scalability machine).
    K80,
    /// CPU-based training (the paper's CPU variant, §4.4).
    CpuOnly,
}

impl GpuModel {
    /// Peak dense fp32 throughput, FLOP/s (used by the roofline cost model).
    pub fn peak_flops(&self) -> f64 {
        match self {
            GpuModel::Rtx3090 => 35.6e12,
            GpuModel::K80 => 4.1e12, // per GK210 die
            GpuModel::CpuOnly => 0.7e12,
        }
    }

    /// Effective memory bandwidth, bytes/s.
    pub fn mem_bw(&self) -> f64 {
        match self {
            GpuModel::Rtx3090 => 936e9,
            GpuModel::K80 => 240e9,
            GpuModel::CpuOnly => 60e9,
        }
    }

    /// Per-step fixed launch/framework overhead.
    pub fn launch_overhead(&self) -> Duration {
        match self {
            GpuModel::Rtx3090 => Duration::from_micros(200),
            GpuModel::K80 => Duration::from_micros(400),
            GpuModel::CpuOnly => Duration::from_micros(50),
        }
    }
}

/// Consumer-side policy when a batch's I/O exhausts the engine retry policy
/// (`--on-io-error`): what a CQE error *means* to training/serving.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnIoError {
    /// Abort the epoch with a typed error (the default: loud, never a hang).
    #[default]
    Fail,
    /// Evict the failed rows and re-extract the batch once; a second
    /// failure aborts (bounded — a permanent bad range must not loop).
    Retry,
    /// Train on the batch with the failed rows zeroed (graceful
    /// degradation: a few lost rows barely move a 1000-node mini-batch).
    DropRows,
}

impl OnIoError {
    /// Case-insensitive CLI lookup.
    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fail" => Some(OnIoError::Fail),
            "retry" => Some(OnIoError::Retry),
            "drop-rows" | "drop_rows" | "drop" => Some(OnIoError::DropRows),
            _ => None,
        }
    }

    /// Valid CLI names, for error messages.
    pub fn names() -> &'static str {
        "fail, retry, drop-rows"
    }
}

/// Fault-injection profile (`--fault-*` CLI flags): the seeded plan plus the
/// retry policy the wrapped backend hands its engines.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultProfile {
    pub plan: FaultPlan,
    pub policy: RetryPolicy,
}

impl Default for FaultProfile {
    fn default() -> Self {
        FaultProfile { plan: FaultPlan::default(), policy: RetryPolicy::default() }
    }
}

#[derive(Clone, Debug)]
pub struct MachineConfig {
    pub name: String,
    pub ssd: SsdConfig,
    /// Host memory budget (simulated capacity, already scaled).
    pub host_mem: u64,
    /// Device memory budget per GPU (scaled).
    pub dev_mem: u64,
    pub pcie: PcieConfig,
    pub gpu: GpuModel,
    /// GPUs available (Fig 13 uses up to 8).
    pub gpus: usize,
    /// Which I/O backend serves reads: the simulated SSD stack (default)
    /// or real OS files (`--backend os`).
    pub backend: BackendKind,
    /// Physical devices the storage stack stripes across (`--devices`;
    /// 1 = the unstriped stack, byte-for-byte).
    pub devices: usize,
    /// RAID-0 chunk size of the stripe (`--stripe-bytes`); ignored at
    /// `devices == 1`.
    pub stripe_bytes: u64,
    /// `pread`-pool threads of the OS backend (`--io-workers`); the pool
    /// splits its workers round-robin across stripe devices.
    pub io_workers: usize,
    /// When set, the selected backend is wrapped in a
    /// [`FaultInjectBackend`] with this profile (`--fault-*` flags).
    pub fault: Option<FaultProfile>,
}

/// Default `--stripe-bytes`: 1 MiB chunks, the common md/RAID-0 default —
/// far wider than a feature row, so rows almost never straddle devices.
pub const DEFAULT_STRIPE_BYTES: u64 = 1 << 20;

impl MachineConfig {
    /// The paper's main testbed: 2×Xeon 6342, 2×RTX 3090 (24 GB), PM883,
    /// 32 GB host memory → scaled 128 MiB host / 96 MiB device.
    pub fn paper() -> Self {
        MachineConfig {
            name: "paper".into(),
            ssd: SsdConfig::pm883(),
            host_mem: 32 * (1 << 30) / MEM_SCALE,
            dev_mem: 24 * (1 << 30) / DEV_MEM_SCALE,
            pcie: PcieConfig::gen3_x16(),
            gpu: GpuModel::Rtx3090,
            gpus: 2,
            backend: BackendKind::Sim,
            devices: 1,
            stripe_bytes: DEFAULT_STRIPE_BYTES,
            io_workers: DEFAULT_POOL_THREADS,
            fault: None,
        }
    }

    /// The Fig 13 machine: 8×K80 (12 GB), S3510, 256 GB (unconstrained).
    pub fn k80() -> Self {
        MachineConfig {
            name: "k80".into(),
            ssd: SsdConfig::s3510(),
            host_mem: 256 * (1 << 30) / MEM_SCALE,
            dev_mem: 12 * (1 << 30) / DEV_MEM_SCALE,
            pcie: PcieConfig::k80(),
            gpu: GpuModel::K80,
            gpus: 8,
            backend: BackendKind::Sim,
            devices: 1,
            stripe_bytes: DEFAULT_STRIPE_BYTES,
            io_workers: DEFAULT_POOL_THREADS,
            fault: None,
        }
    }

    /// Select the I/O backend (CLI `--backend sim|os`).
    pub fn with_backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    /// Stripe the storage stack across `devices` physical devices
    /// (`--devices`; clamped to ≥ 1).
    pub fn with_devices(mut self, devices: usize) -> Self {
        self.devices = devices.max(1);
        self
    }

    /// RAID-0 chunk size (`--stripe-bytes`; clamped to ≥ 1 byte).
    pub fn with_stripe_bytes(mut self, bytes: u64) -> Self {
        self.stripe_bytes = bytes.max(1);
        self
    }

    /// OS-backend `pread` pool width (`--io-workers`; clamped to ≥ 1).
    pub fn with_io_workers(mut self, workers: usize) -> Self {
        self.io_workers = workers.max(1);
        self
    }

    /// The stripe geometry this config describes (`single()` at
    /// `devices == 1`, where `stripe_bytes` is ignored).
    pub fn stripe_spec(&self) -> StripeSpec {
        StripeSpec::new(self.devices.max(1), self.stripe_bytes.max(1))
    }

    /// Wrap the selected backend in seeded fault injection (`--fault-*`).
    pub fn with_fault(mut self, profile: FaultProfile) -> Self {
        self.fault = Some(profile);
        self
    }

    /// Override the host memory budget (Fig 9 sweeps 8–128 GB paper-scale).
    pub fn with_host_mem(mut self, bytes: u64) -> Self {
        self.host_mem = bytes;
        self
    }

    /// Paper-scale helper: `with_paper_host_gb(32)` → 128 MiB simulated.
    pub fn with_paper_host_gb(self, gb: u64) -> Self {
        let bytes = gb * (1 << 30) / MEM_SCALE;
        self.with_host_mem(bytes)
    }

    /// Load overrides from a TOML-subset file onto a preset base.
    pub fn from_file(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        let doc = Doc::parse(&text)?;
        let mut cfg = match doc.get_str("base").unwrap_or("paper") {
            "paper" => MachineConfig::paper(),
            "k80" => MachineConfig::k80(),
            other => return Err(format!("unknown base machine {other:?}")),
        };
        if let Some(name) = doc.get_str("name") {
            cfg.name = name.to_string();
        }
        if let Some(v) = doc.get_str("host_mem") {
            cfg.host_mem = units::parse_bytes(v)?;
        }
        if let Some(v) = doc.get_str("dev_mem") {
            cfg.dev_mem = units::parse_bytes(v)?;
        }
        if let Some(v) = doc.get_str("ssd.read_bw") {
            cfg.ssd.read_bw = units::parse_bytes(v)? as f64;
        }
        if let Some(v) = doc.get_str("ssd.write_bw") {
            cfg.ssd.write_bw = units::parse_bytes(v)? as f64;
        }
        if let Some(v) = doc.get_str("ssd.latency") {
            cfg.ssd.latency = units::parse_duration(v)?;
        }
        if let Some(v) = doc.get_f64("ssd.iops") {
            cfg.ssd.iops = v;
        }
        if let Some(v) = doc.get_i64("ssd.queue_depth") {
            cfg.ssd.queue_depth = v as usize;
        }
        if let Some(v) = doc.get_i64("gpus") {
            cfg.gpus = v as usize;
        }
        if let Some(v) = doc.get_i64("devices") {
            cfg.devices = (v as usize).max(1);
        }
        if let Some(v) = doc.get_str("stripe_bytes") {
            cfg.stripe_bytes = units::parse_bytes(v)?.max(1);
        }
        if let Some(v) = doc.get_i64("io_workers") {
            cfg.io_workers = (v as usize).max(1);
        }
        if let Some(v) = doc.get_str("backend") {
            cfg.backend = BackendKind::by_name(v)
                .ok_or_else(|| format!("unknown backend {v:?} (valid: {})", BackendKind::names()))?;
        }
        if let Some(v) = doc.get_str("gpu") {
            cfg.gpu = match v {
                "rtx3090" => GpuModel::Rtx3090,
                "k80" => GpuModel::K80,
                "cpu" => GpuModel::CpuOnly,
                other => return Err(format!("unknown gpu {other:?}")),
            };
        }
        Ok(cfg)
    }
}

/// The instantiated shared substrate: one I/O backend, one host memory
/// budget, one PCIe link, `gpus` device memory budgets.
///
/// `storage` is always the concrete simulated stack (sim-only experiments
/// poke its `ssd`/`cache` directly); `backend` is the *selected*
/// [`IoBackend`] every consumer routes reads through. With the default
/// `BackendKind::Sim` the two are the same object, so SSD-charge accounting
/// is observable through either handle.
pub struct Machine {
    pub cfg: MachineConfig,
    pub clock: Clock,
    pub storage: Storage,
    pub host: HostMemory,
    pub devices: Vec<DeviceMemory>,
    pub pcie: Arc<Pcie>,
    pub backend: Arc<dyn IoBackend>,
}

impl Machine {
    pub fn new(cfg: MachineConfig, clock: Clock) -> Self {
        let spec = cfg.stripe_spec();
        let host = HostMemory::new(cfg.host_mem);
        let cache = Arc::new(PageCache::new(host.clone()));
        // Striped sim: one independent SsdSim per device on the shared
        // clock, so charged latency reflects N IOPS/queue-depth ceilings.
        let storage = if spec.is_striped() {
            let ssds =
                (0..spec.devices).map(|_| SsdSim::new(cfg.ssd.clone(), clock.clone())).collect();
            Storage::new_striped(ssds, cache, cfg.stripe_bytes)
        } else {
            Storage::new(SsdSim::new(cfg.ssd.clone(), clock.clone()), cache)
        };
        // `--backend uring` is runtime-gated: a failed probe (old kernel,
        // seccomp, unsupported arch) warns once and builds the `os` pread
        // stack instead — the typed-fallback contract of ISSUE 9. The
        // resolved kind also steers the fault wrapper's engine choice.
        let resolved_kind = match cfg.backend {
            BackendKind::Uring => match crate::storage::probe_uring() {
                Ok(()) => BackendKind::Uring,
                Err(e) => {
                    eprintln!(
                        "[config] WARN: --backend uring unavailable ({e}); \
                         falling back to the os pread backend"
                    );
                    BackendKind::Os
                }
            },
            other => other,
        };
        let mut backend: Arc<dyn IoBackend> = match resolved_kind {
            BackendKind::Sim => Arc::new(storage.clone()),
            BackendKind::Os => {
                Arc::new(OsFileBackend::with_stripe(cfg.ssd.sector, cfg.io_workers, spec))
            }
            BackendKind::Uring => {
                Arc::new(OsFileBackend::with_stripe_uring(cfg.ssd.sector, cfg.io_workers, spec))
            }
        };
        if let Some(profile) = &cfg.fault {
            backend = Arc::new(
                FaultInjectBackend::new(
                    backend,
                    resolved_kind,
                    profile.plan.clone(),
                    profile.policy,
                    clock.clone(),
                )
                .with_io_workers(cfg.io_workers),
            );
        }
        let devices = (0..cfg.gpus.max(1)).map(|_| DeviceMemory::new(cfg.dev_mem)).collect();
        let pcie = Pcie::new(cfg.pcie.clone(), clock.clone());
        Machine { cfg, clock, storage, host, devices, pcie, backend }
    }

    pub fn paper_default() -> Self {
        Machine::new(MachineConfig::paper(), Clock::from_env())
    }
}

/// Sample–extract–train workload parameters (defaults follow §5).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub batch_size: usize,
    /// Neighbor fanout per layer, innermost (layer-1) first, e.g. [10,10,10].
    pub fanouts: Vec<usize>,
    pub epochs: usize,
    /// Optional cap on mini-batches per epoch (quick benches).
    pub batches_per_epoch: Option<usize>,
    pub samplers: usize,
    pub extractors: usize,
    /// Extracting-queue capacity (paper: 6) and training-queue depth (4).
    pub extract_queue_cap: usize,
    pub train_queue_cap: usize,
    /// Feature-buffer size multiplier over the minimum (Fig 12 sweeps 1–8×).
    pub feature_buffer_mult: usize,
    /// io_uring depth per extractor.
    pub io_depth: usize,
    /// Max bytes one coalesced extraction segment may span
    /// (`--coalesce-bytes`; 0 disables coalescing — one request per row).
    pub coalesce_bytes: usize,
    /// Strict upper bound on the bridged byte gap between rows merged into
    /// one segment (`--coalesce-gap`).
    pub coalesce_gap: usize,
    /// Pin the adaptive coalescing governor off: the effective per-device
    /// config stays at the base values forever. Set by `main.rs` whenever
    /// either coalesce flag was passed explicitly — the user's setting is
    /// the experiment.
    pub coalesce_pinned: bool,
    /// Hedged reissue of straggler extraction segments (`--hedge`): when a
    /// wave's in-flight segments exceed the p99 completion latency, re-issue
    /// them into fresh staging ranges and take whichever copy lands first.
    pub hedge: bool,
    /// Pinned hedge threshold in µs (`--hedge-us`); `None` derives the
    /// threshold adaptively from the observed p99 segment latency.
    pub hedge_us: Option<u64>,
    pub seed: u64,
    pub learning_rate: f32,
    /// Data-parallel segment `(worker, of_n)`: this pipeline trains the
    /// strided subset `train_ids[worker::of_n]` (Fig 13, §4.3).
    pub segment: Option<(usize, usize)>,
    /// Ablation: synchronous extraction (no io_uring overlap).
    pub sync_extract: bool,
    /// Ablation: feature reads through the page cache instead of direct I/O.
    pub buffered_features: bool,
    /// Ablation: force in-order training (disable mini-batch reordering).
    pub enforce_order: bool,
    /// Batch-level policy when extraction I/O exhausts the engine retry
    /// policy (`--on-io-error fail|retry|drop-rows`).
    pub on_io_error: OnIoError,
    /// Feature placement tier (`--tier host|gpu`). `Host` is the pre-tier
    /// single-buffer path, byte- and charge-identical to it; `Gpu` layers a
    /// device-resident hot tier above the host buffer.
    pub tier: crate::tier::TierKind,
    /// GPU hot-tier capacity in bytes (`--gpu-mem`); required (> 0) when
    /// `tier == Gpu`, ignored otherwise.
    pub gpu_mem: u64,
    /// UVM-style oversubscription ablation (`--gpu-oversub`): the GPU tier
    /// admits past capacity and pays a modeled fault-migration transfer per
    /// over-capacity access instead of demoting.
    pub gpu_oversub: bool,
}

impl TrainConfig {
    /// The deterministic batch/sampling schedule this config runs — the
    /// single value the pipeline engine, `run_sample_only`, and the offline
    /// `layout/` pre-sampler all derive their batches from, so a packed
    /// dataset replays training's exact batch sequence.
    pub fn schedule_spec(&self) -> crate::sample::ScheduleSpec {
        crate::sample::ScheduleSpec {
            seed: self.seed,
            batch_size: self.batch_size,
            fanouts: self.fanouts.clone(),
            batches_per_epoch: self.batches_per_epoch,
        }
    }
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch_size: 1000,
            fanouts: vec![10, 10, 10],
            epochs: 1,
            batches_per_epoch: None,
            samplers: 4,
            extractors: 4,
            extract_queue_cap: 6,
            train_queue_cap: 4,
            feature_buffer_mult: 1,
            io_depth: 128,
            coalesce_bytes: crate::extract::CoalesceConfig::default().max_bytes,
            coalesce_gap: crate::extract::CoalesceConfig::default().gap_bytes,
            coalesce_pinned: false,
            hedge: false,
            hedge_us: None,
            seed: 17,
            learning_rate: 0.01,
            segment: None,
            sync_extract: false,
            buffered_features: false,
            enforce_order: false,
            on_io_error: OnIoError::default(),
            tier: crate::tier::TierKind::Host,
            gpu_mem: 0,
            gpu_oversub: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_scale_memory() {
        let paper = MachineConfig::paper();
        assert_eq!(paper.host_mem, 128 << 20);
        assert_eq!(paper.dev_mem, 768 << 20);
        let k80 = MachineConfig::k80();
        assert_eq!(k80.gpus, 8);
        assert_eq!(k80.host_mem, 1 << 30);
    }

    #[test]
    fn paper_host_gb_helper() {
        let m = MachineConfig::paper().with_paper_host_gb(8);
        assert_eq!(m.host_mem, 32 << 20);
    }

    #[test]
    fn machine_instantiates_substrate() {
        let m = Machine::new(MachineConfig::paper(), Clock::new(1.0));
        assert_eq!(m.devices.len(), 2);
        assert_eq!(m.host.capacity(), 128 << 20);
        assert_eq!(m.storage.ssd.config().sector, 512);
    }

    #[test]
    fn backend_selection_plumbs_through() {
        let m = Machine::new(MachineConfig::paper(), Clock::new(1.0));
        assert_eq!(m.backend.name(), "sim");
        let m = Machine::new(
            MachineConfig::paper().with_backend(BackendKind::Os),
            Clock::new(1.0),
        );
        assert_eq!(m.backend.name(), "os");
        assert_eq!(m.backend.sector(), 512);
    }

    #[test]
    fn uring_backend_probes_and_falls_back_typed() {
        let m = Machine::new(
            MachineConfig::paper().with_backend(BackendKind::Uring),
            Clock::new(1.0),
        );
        // Kernel-dependent but never wrong: a passing probe yields the real
        // uring backend, a failing one the documented os fallback.
        match crate::storage::probe_uring() {
            Ok(()) => assert_eq!(m.backend.name(), "uring"),
            Err(_) => assert_eq!(m.backend.name(), "os"),
        }
        assert_eq!(m.backend.sector(), 512);
    }

    #[test]
    fn striped_machine_builds_per_device_stack() {
        let cfg = MachineConfig::paper().with_devices(3).with_stripe_bytes(4096);
        let m = Machine::new(cfg, Clock::new(1.0));
        assert_eq!(m.backend.stripe(), StripeSpec::new(3, 4096));
        assert_eq!(m.backend.device_io_snapshot().len(), 3);
        m.backend.charge_multi_dev(1, 1, 4096);
        let snap = m.backend.device_io_snapshot();
        assert_eq!(snap[0].0, 0);
        assert_eq!(snap[1], (1, 4096));
        assert_eq!(snap[2].0, 0);
        // The aggregate surface mirrors per-device charges.
        assert_eq!(
            m.backend.io_counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            1
        );

        // OS backend: geometry + io-workers plumb through; the fault
        // wrapper is transparent to both.
        let cfg = MachineConfig::paper()
            .with_backend(BackendKind::Os)
            .with_devices(2)
            .with_stripe_bytes(8192)
            .with_io_workers(3)
            .with_fault(FaultProfile::default());
        let m = Machine::new(cfg, Clock::new(1.0));
        assert_eq!(m.backend.name(), "os+fault");
        assert_eq!(m.backend.stripe(), StripeSpec::new(2, 8192));
        assert_eq!(m.backend.device_io_snapshot().len(), 2);
    }

    #[test]
    fn fault_profile_wraps_selected_backend() {
        let cfg = MachineConfig::paper().with_fault(FaultProfile {
            plan: FaultPlan::transient(99, 0.01),
            policy: RetryPolicy::default(),
        });
        let m = Machine::new(cfg, Clock::new(1.0));
        assert_eq!(m.backend.name(), "sim+fault");
        assert_eq!(m.backend.sector(), 512);
        // Accounting surfaces delegate to the wrapped backend: charges land
        // on the same counters sim-only experiments poke directly.
        m.backend.charge_multi(1, 4096);
        assert_eq!(
            m.storage.ssd.counters().reads.load(std::sync::atomic::Ordering::Relaxed),
            1
        );
        assert_eq!(OnIoError::by_name("DROP-ROWS"), Some(OnIoError::DropRows));
        assert_eq!(OnIoError::by_name("bogus"), None);
        assert_eq!(OnIoError::default(), OnIoError::Fail);
    }

    #[test]
    fn config_file_overrides() {
        let dir = std::env::temp_dir().join("gnndrive_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.toml");
        std::fs::write(
            &path,
            "base = \"paper\"\nhost_mem = \"64MiB\"\ngpus = 1\ndevices = 3\nstripe_bytes = \"64KiB\"\nio_workers = 12\n[ssd]\nlatency = \"120us\"\niops = 50000\n",
        )
        .unwrap();
        let cfg = MachineConfig::from_file(&path).unwrap();
        assert_eq!(cfg.host_mem, 64 << 20);
        assert_eq!(cfg.gpus, 1);
        assert_eq!(cfg.ssd.latency, Duration::from_micros(120));
        assert_eq!(cfg.ssd.iops, 50000.0);
        assert_eq!(cfg.devices, 3);
        assert_eq!(cfg.stripe_bytes, 64 << 10);
        assert_eq!(cfg.io_workers, 12);
    }
}
