//! Tiered feature placement: a simulated-GPU-resident hot tier above the
//! host [`FeatureBuffer`].
//!
//! GNNDrive's host feature buffer is one PCIe hop away from compute. Skewed
//! workloads (power-law degrees, a serving hot head) concentrate most
//! feature traffic on a small set of rows that could live *in* device
//! memory instead: Data Tiering (arxiv 2111.05894) shows frequency/degree-
//! weighted placement of hot features in GPU memory removes most
//! host↔device transfer from the critical path, and Ginex (arxiv
//! 2208.09151) shows how much a good admission/eviction policy beats LRU on
//! exactly this access pattern.
//!
//! [`TieredFeatureStore`] is the single façade the pipeline and the serve
//! engine talk to. In `--tier host` mode it is a pure delegate to the
//! wrapped [`FeatureBuffer`] — no extra state, no extra charges, byte- and
//! charge-identical to the pre-tier stack. In `--tier gpu` mode it layers a
//! [`GpuTier`] above the host buffer:
//!
//! * **Placement.** A batch resolves each node GPU tier → host buffer →
//!   SSD. GPU residents are aliased as `fb.n_slots + gpu_slot` (the alias
//!   space above the host arena), so one `i32` alias vector still describes
//!   the whole batch and `gather`/`release_aliases` split it by range.
//! * **Promotion.** A node that hits in the *host* buffer repeatedly
//!   (frequency ≥ threshold, with the threshold lowered for above-average-
//!   degree nodes — the Data-Tiering degree prior) is copied up into the
//!   GPU arena. The copy is charged to the PCIe model (`transfer_sync`),
//!   and the node's host row is released back to the host buffer off the
//!   critical path, so a row is resident in at most one tier once the
//!   pipeline quiesces.
//! * **Demotion.** Victim selection mirrors the host buffer's second-chance
//!   clock over packed atomic slot words ([`slot_state`]), but the actual
//!   unmapping is batched through a bounded queue drained by a background
//!   demoter thread — eviction work stays off the extraction critical path.
//!   Demotion moves no bytes (tier rows are clean copies of SSD truth).
//! * **Admission.** One-off cold seeds — nodes seen for the first time that
//!   had to be loaded from SSD — bypass both tiers: they are never promoted
//!   and their host row is dropped back to the free list as soon as it
//!   idles, so cold scans cannot wash out the hot set.
//! * **Oversubscription ablation** (`--gpu-oversub`). Instead of demoting,
//!   the tier admits past capacity into a UVM-style spill region and pays a
//!   modeled fault-migration transfer for every access to an over-capacity
//!   row — the naive alternative the bench compares explicit tiering
//!   against.
//!
//! Charging contract: the GPU tier charges the PCIe link for promotions,
//! pinned-layout uploads, and oversubscription faults, and it *saves* one
//! row transfer per GPU hit (`pcie_saved_bytes`). SSD charging is untouched
//! — only the host buffer loads from storage. See `membuf/mod.rs` and
//! `storage/mod.rs` for the cross-layer contract.

use crate::membuf::{slot_state, BatchPlan, FeatureBuffer};
use crate::storage::mem::{DeviceMemory, OutOfMemory, Reservation};
use crate::storage::pcie::Pcie;
use std::cell::UnsafeCell;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::sim::queue::BoundedQueue;

/// Which placement stack a run uses (`--tier`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TierKind {
    /// Single-tier host buffer — the pre-tier stack, charge-identical.
    #[default]
    Host,
    /// GPU-resident hot tier above the host buffer.
    Gpu,
}

impl TierKind {
    pub fn by_name(name: &str) -> Option<TierKind> {
        match name.to_ascii_lowercase().as_str() {
            "host" => Some(TierKind::Host),
            "gpu" => Some(TierKind::Gpu),
            _ => None,
        }
    }

    pub fn names() -> &'static str {
        "host|gpu"
    }

    pub fn label(self) -> &'static str {
        match self {
            TierKind::Host => "host",
            TierKind::Gpu => "gpu",
        }
    }
}

/// Placement policy knobs for the GPU tier.
#[derive(Clone, Debug)]
pub struct TierPolicy {
    /// Host hits before a node is promoted (frequency threshold). The
    /// effective threshold drops by one (floor 1) for nodes whose degree is
    /// above the graph average — high-degree nodes are structurally hot.
    pub promote_threshold: u32,
    /// UVM-style oversubscription ablation: admit past capacity into a
    /// spill region and pay a fault-migration transfer per access.
    pub oversub: bool,
    /// CSR `indptr` of the training graph, for the degree prior. `None`
    /// disables degree weighting (pure frequency).
    pub indptr: Option<Arc<Vec<u64>>>,
}

impl Default for TierPolicy {
    fn default() -> Self {
        TierPolicy { promote_threshold: 2, oversub: false, indptr: None }
    }
}

/// Monotonic per-tier counters; epoch deltas via [`TierSnapshot::since`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierSnapshot {
    /// Batch nodes served out of the GPU arena.
    pub gpu_hits: u64,
    /// Batch nodes served out of the host buffer (hit or shared wait).
    pub host_hits: u64,
    /// Rows copied host → GPU by the placement policy.
    pub promotions: u64,
    /// Rows unmapped from the GPU arena by the background demoter.
    pub demotions: u64,
    /// One-off cold seeds whose host row was dropped early (admission
    /// bypass).
    pub bypassed: u64,
    /// Accesses to over-capacity (spill-region) rows under `--gpu-oversub`.
    pub oversub_faults: u64,
    /// Host→device row transfers avoided because the row was GPU-resident.
    pub pcie_saved_bytes: u64,
    /// PCIe bytes the tier itself charged (promotions + pinned uploads +
    /// oversubscription fault migrations).
    pub pcie_tier_bytes: u64,
}

impl TierSnapshot {
    /// Delta since an earlier snapshot of the same store.
    pub fn since(&self, start: &TierSnapshot) -> TierSnapshot {
        TierSnapshot {
            gpu_hits: self.gpu_hits - start.gpu_hits,
            host_hits: self.host_hits - start.host_hits,
            promotions: self.promotions - start.promotions,
            demotions: self.demotions - start.demotions,
            bypassed: self.bypassed - start.bypassed,
            oversub_faults: self.oversub_faults - start.oversub_faults,
            pcie_saved_bytes: self.pcie_saved_bytes - start.pcie_saved_bytes,
            pcie_tier_bytes: self.pcie_tier_bytes - start.pcie_tier_bytes,
        }
    }

    /// Merge another snapshot in (per-tenant report aggregation).
    pub fn merge(&mut self, other: &TierSnapshot) {
        self.gpu_hits += other.gpu_hits;
        self.host_hits += other.host_hits;
        self.promotions += other.promotions;
        self.demotions += other.demotions;
        self.bypassed += other.bypassed;
        self.oversub_faults += other.oversub_faults;
        self.pcie_saved_bytes += other.pcie_saved_bytes;
        self.pcie_tier_bytes += other.pcie_tier_bytes;
    }

    /// Fraction of buffered hits the GPU tier served (the bench's ≥80%
    /// hot-head gate).
    pub fn gpu_hit_fraction(&self) -> f64 {
        let total = self.gpu_hits + self.host_hits;
        if total == 0 {
            0.0
        } else {
            self.gpu_hits as f64 / total as f64
        }
    }
}

/// Degree prior for promotion: above-average-degree nodes promote one hit
/// earlier.
struct Degrees {
    indptr: Arc<Vec<u64>>,
    avg: u64,
}

impl Degrees {
    fn new(indptr: Arc<Vec<u64>>) -> Self {
        let nodes = indptr.len().saturating_sub(1).max(1) as u64;
        let edges = indptr.last().copied().unwrap_or(0);
        Degrees { avg: edges / nodes, indptr }
    }

    fn degree(&self, node: u32) -> u64 {
        let v = node as usize;
        if v + 1 >= self.indptr.len() {
            return 0;
        }
        self.indptr[v + 1] - self.indptr[v]
    }
}

/// Flat f32 row arena for the GPU tier. A row is written only while its
/// slot is unmapped and invalid (exclusive ownership under the tier lock)
/// and read only through a published alias whose batch holds a reference,
/// so the raw-pointer copies never overlap; the happens-before edge is the
/// SeqCst store of the slot word on publish against the acquire load before
/// a gather (the same protocol as the host buffer's arena).
struct RowArena {
    data: UnsafeCell<Box<[f32]>>,
    dim: usize,
}

unsafe impl Sync for RowArena {}

impl RowArena {
    fn new(rows: usize, dim: usize) -> Self {
        RowArena { data: UnsafeCell::new(vec![0.0f32; rows * dim].into_boxed_slice()), dim }
    }

    /// Safety: caller owns `slot` exclusively (unmapped + invalid, under
    /// the tier lock).
    unsafe fn write_row(&self, slot: usize, row: &[f32]) {
        debug_assert_eq!(row.len(), self.dim);
        let dst = (*self.data.get()).as_mut_ptr().add(slot * self.dim);
        std::ptr::copy_nonoverlapping(row.as_ptr(), dst, self.dim);
    }

    /// Safety: as [`RowArena::write_row`]; decodes little-endian f32 bytes
    /// (the on-disk feature format). Tolerates longer byte slices exactly
    /// like `FeatureBuffer::publish_le_bytes` (padded layout rows).
    unsafe fn write_row_le(&self, slot: usize, bytes: &[u8]) {
        let n = self.dim.min(bytes.len() / 4);
        let dst = (*self.data.get()).as_mut_ptr().add(slot * self.dim);
        for (i, chunk) in bytes.chunks_exact(4).take(n).enumerate() {
            *dst.add(i) = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
    }

    /// Safety: caller holds a reference on a valid slot and performed an
    /// acquire load of its slot word.
    unsafe fn read_row(&self, slot: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim);
        let src = (*self.data.get()).as_ptr().add(slot * self.dim);
        std::ptr::copy_nonoverlapping(src, out.as_mut_ptr(), self.dim);
    }
}

/// Sentinel for "slot holds no tenant" in `Inner::slot_node`.
const NO_NODE: u32 = u32::MAX;

/// Victims the clock sweep hands to the demoter per allocation failure.
const SWEEP_ENQUEUE_MAX: usize = 32;

/// Demoter batch size: victims unmapped per queue drain.
const DEMOTE_BATCH: usize = 64;

/// Mutable tier state: the mapping table and free lists. One mutex — the
/// tier is consulted once per batch (tens to thousands of nodes), not per
/// row, and every refcount *increment* happens under this lock, which is
/// what makes the demoter's refs==0 check stable (releases only decrement).
struct Inner {
    /// node → GPU slot.
    map: HashMap<u32, u32>,
    /// slot → tenant node, `NO_NODE` when unmapped.
    slot_node: Vec<u32>,
    /// Pinned (packed-layout) slots: never demoted.
    pinned: Vec<bool>,
    /// Free device-resident slots (`< capacity`).
    free: Vec<u32>,
    /// Freed spill-region slots (oversubscription only).
    spill_free: Vec<u32>,
    /// Next never-used spill slot; starts at `capacity`.
    spill_next: usize,
    /// Access frequency per node (the promotion signal).
    freq: HashMap<u32, u32>,
    /// Promoted nodes whose *host* row still needs eviction (exclusivity).
    pending_host_evict: Vec<u32>,
    /// One-off cold seeds (node → drain age). A candidate ages one step
    /// per drain and is only dropped at age ≥ 1, so a node re-accessed in
    /// the very next batch is rescued before its host row is torn down.
    bypass_pending: HashMap<u32, u32>,
    /// Second-chance clock cursor over the device-resident region.
    hand: usize,
    /// Demotion order observed by unit tests.
    #[cfg(test)]
    demote_log: Vec<u32>,
}

/// The simulated-GPU-resident hot tier: its own slot arena + packed atomic
/// slot words, capacity charged to [`DeviceMemory`], transfers charged to
/// the [`Pcie`] model.
pub struct GpuTier {
    dim: usize,
    row_bytes: usize,
    /// Device-resident rows (`--gpu-mem / row_bytes`).
    capacity: usize,
    /// Total arena rows: `capacity`, or `2 × capacity` with the
    /// oversubscription spill region.
    arena_rows: usize,
    oversub: bool,
    promote_threshold: u32,
    degrees: Option<Degrees>,
    states: slot_state::SlotStates,
    arena: RowArena,
    inner: Mutex<Inner>,
    pcie: Arc<Pcie>,
    demote_q: BoundedQueue<u32>,
    gpu_hits: AtomicU64,
    host_hits: AtomicU64,
    promotions: AtomicU64,
    demotions: AtomicU64,
    bypassed: AtomicU64,
    oversub_faults: AtomicU64,
    pcie_saved_bytes: AtomicU64,
    pcie_tier_bytes: AtomicU64,
    _reservation: Reservation,
}

impl GpuTier {
    fn new(
        fb: &FeatureBuffer,
        device: &DeviceMemory,
        pcie: Arc<Pcie>,
        gpu_mem: u64,
        policy: &TierPolicy,
    ) -> Result<GpuTier, OutOfMemory> {
        let dim = fb.dim;
        let row_bytes = dim * 4;
        let reservation = device.reserve("gpu hot tier", gpu_mem)?;
        let capacity = ((gpu_mem as usize) / row_bytes).max(1);
        let arena_rows = if policy.oversub { capacity * 2 } else { capacity };
        // GPU aliases live above the host arena in i32 alias space.
        assert!(
            fb.n_slots + arena_rows < i32::MAX as usize,
            "combined alias space overflows i32"
        );
        Ok(GpuTier {
            dim,
            row_bytes,
            capacity,
            arena_rows,
            oversub: policy.oversub,
            promote_threshold: policy.promote_threshold.max(1),
            degrees: policy.indptr.clone().map(Degrees::new),
            states: slot_state::SlotStates::new(arena_rows),
            arena: RowArena::new(arena_rows, dim),
            inner: Mutex::new(Inner {
                map: HashMap::new(),
                slot_node: vec![NO_NODE; arena_rows],
                pinned: vec![false; arena_rows],
                // Descending push so pops hand out ascending slot ids
                // (diagnostic friendliness, same as the host free stack).
                free: (0..capacity as u32).rev().collect(),
                spill_free: Vec::new(),
                spill_next: capacity,
                freq: HashMap::new(),
                pending_host_evict: Vec::new(),
                bypass_pending: HashMap::new(),
                hand: 0,
                #[cfg(test)]
                demote_log: Vec::new(),
            }),
            pcie,
            demote_q: BoundedQueue::new(1024),
            gpu_hits: AtomicU64::new(0),
            host_hits: AtomicU64::new(0),
            promotions: AtomicU64::new(0),
            demotions: AtomicU64::new(0),
            bypassed: AtomicU64::new(0),
            oversub_faults: AtomicU64::new(0),
            pcie_saved_bytes: AtomicU64::new(0),
            pcie_tier_bytes: AtomicU64::new(0),
            _reservation: reservation,
        })
    }

    /// Effective promotion threshold for `node` (degree prior).
    fn threshold_for(&self, node: u32) -> u32 {
        match &self.degrees {
            Some(d) if d.degree(node) > d.avg => (self.promote_threshold - 1).max(1),
            _ => self.promote_threshold,
        }
    }

    /// Take one reference on a mapped slot. Called under the tier lock, so
    /// the generation is stable and the CAS loop converges; the CAS also
    /// sets the clock bit (the slot was just used).
    fn take_ref(&self, slot: u32) {
        loop {
            let w = self.states.load(slot);
            if self.states.try_ref(slot, slot_state::generation(w)).is_ok() {
                return;
            }
        }
    }

    /// Pop a free slot: device region first, then (oversubscription only)
    /// the spill region.
    fn alloc_slot(&self, inner: &mut Inner) -> Option<u32> {
        if let Some(s) = inner.free.pop() {
            return Some(s);
        }
        if self.oversub {
            if let Some(s) = inner.spill_free.pop() {
                return Some(s);
            }
            if inner.spill_next < self.arena_rows {
                let s = inner.spill_next as u32;
                inner.spill_next += 1;
                return Some(s);
            }
        }
        None
    }

    /// Second-chance clock sweep over the device region: clear the clock
    /// bit where it is set, enqueue zero-reference unpinned slots whose bit
    /// was already clear for the background demoter. Mirrors the host
    /// buffer's discipline, but the unmapping itself is deferred off this
    /// path. One cycle per call: a slot whose bit this call cleared is only
    /// demotable by a *later* sweep, so every resident genuinely gets its
    /// second chance even under a burst of allocation failures.
    fn sweep_victims(&self, inner: &mut Inner) {
        if self.oversub || self.capacity == 0 {
            // The ablation never demotes: it spills instead.
            return;
        }
        let mut enqueued = 0usize;
        for _ in 0..self.capacity {
            let s = inner.hand % self.capacity;
            inner.hand = inner.hand.wrapping_add(1);
            let node = inner.slot_node[s];
            if node == NO_NODE || inner.pinned[s] {
                continue;
            }
            let w = self.states.load(s as u32);
            if slot_state::refs(w) != 0 {
                continue;
            }
            if slot_state::has_clock(w) {
                self.states.clear_clock(s as u32);
                continue;
            }
            if self.demote_q.try_push(node).is_err() {
                break; // queue full or closed: the demoter will catch up
            }
            enqueued += 1;
            if enqueued >= SWEEP_ENQUEUE_MAX {
                break;
            }
        }
    }

    /// Unmap a batch of demotion victims (demoter thread / test flush).
    /// Every reference increment happens under the tier lock, so refs==0
    /// observed here cannot be raced upward; a clock bit set since the
    /// sweep means the row was re-used and gets its second chance.
    fn process_victims(&self, nodes: &[u32]) {
        if nodes.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for &n in nodes {
            let Some(&slot) = inner.map.get(&n) else { continue };
            if inner.pinned[slot as usize] {
                continue;
            }
            let w = self.states.load(slot);
            if slot_state::refs(w) != 0 || slot_state::has_clock(w) {
                continue;
            }
            inner.map.remove(&n);
            inner.slot_node[slot as usize] = NO_NODE;
            self.states.reset(slot, 0, false, slot_state::generation(w).wrapping_add(1));
            if (slot as usize) < self.capacity {
                inner.free.push(slot);
            } else {
                inner.spill_free.push(slot);
            }
            self.demotions.fetch_add(1, Ordering::Relaxed);
            #[cfg(test)]
            inner.demote_log.push(n);
        }
    }

    /// Apply deferred host-side bookkeeping off the allocation path:
    /// release the host rows of freshly promoted nodes (tier exclusivity)
    /// and drop the host rows of one-off cold seeds (admission bypass).
    /// Rows still referenced by in-flight batches are retried next call.
    fn drain_pending(&self, fb: &FeatureBuffer) {
        let (evicts, bypass) = {
            let mut inner = self.inner.lock().unwrap();
            if inner.pending_host_evict.is_empty() && inner.bypass_pending.is_empty() {
                return;
            }
            let evicts = std::mem::take(&mut inner.pending_host_evict);
            // Only ripe candidates (age ≥ 1) are dropped; the rest age one
            // step, giving a node one batch window to prove it is not a
            // one-off.
            let mut bypass = Vec::new();
            for (&n, age) in inner.bypass_pending.iter_mut() {
                if *age >= 1 {
                    bypass.push(n);
                } else {
                    *age += 1;
                }
            }
            (evicts, bypass)
        };
        let mut retry = Vec::new();
        for n in evicts {
            if fb.is_resident(n) && fb.evict_if_idle(&[n]) == 0 {
                retry.push(n);
            }
        }
        let mut done = Vec::new();
        let mut bypassed = 0u64;
        for n in bypass {
            if !fb.is_resident(n) {
                done.push(n); // dropped or naturally evicted already
            } else if fb.evict_if_idle(&[n]) == 1 {
                bypassed += 1;
                done.push(n);
            }
        }
        if bypassed > 0 {
            self.bypassed.fetch_add(bypassed, Ordering::Relaxed);
        }
        let mut inner = self.inner.lock().unwrap();
        inner.pending_host_evict.extend(retry);
        for n in done {
            inner.bypass_pending.remove(&n);
        }
    }

    fn snapshot(&self) -> TierSnapshot {
        TierSnapshot {
            gpu_hits: self.gpu_hits.load(Ordering::Relaxed),
            host_hits: self.host_hits.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            demotions: self.demotions.load(Ordering::Relaxed),
            bypassed: self.bypassed.load(Ordering::Relaxed),
            oversub_faults: self.oversub_faults.load(Ordering::Relaxed),
            pcie_saved_bytes: self.pcie_saved_bytes.load(Ordering::Relaxed),
            pcie_tier_bytes: self.pcie_tier_bytes.load(Ordering::Relaxed),
        }
    }
}

/// The two-tier façade the pipeline and serve engine construct behind
/// `--tier`. Host mode delegates everything to the wrapped buffer; GPU
/// mode splits each batch across the tiers.
pub struct TieredFeatureStore {
    fb: Arc<FeatureBuffer>,
    gpu: Option<Arc<GpuTier>>,
    demoter: Mutex<Option<JoinHandle<()>>>,
}

impl TieredFeatureStore {
    /// `--tier host`: a pure delegate. No tier state is allocated, nothing
    /// extra is ever charged — byte- and charge-identical to handing the
    /// [`FeatureBuffer`] out directly.
    pub fn host(fb: Arc<FeatureBuffer>) -> Arc<TieredFeatureStore> {
        Arc::new(TieredFeatureStore { fb, gpu: None, demoter: Mutex::new(None) })
    }

    /// `--tier gpu`: layer a GPU-resident hot tier of `gpu_mem` bytes
    /// (reserved against `device`) above `fb`, with transfers charged to
    /// `pcie`.
    pub fn gpu(
        fb: Arc<FeatureBuffer>,
        device: &DeviceMemory,
        pcie: Arc<Pcie>,
        gpu_mem: u64,
        policy: TierPolicy,
    ) -> Result<Arc<TieredFeatureStore>, OutOfMemory> {
        let gpu = Arc::new(GpuTier::new(&fb, device, pcie, gpu_mem, &policy)?);
        let worker = {
            let g = gpu.clone();
            std::thread::Builder::new()
                .name("tier-demoter".into())
                .spawn(move || {
                    while let Ok(first) = g.demote_q.pop() {
                        let mut batch = Vec::with_capacity(DEMOTE_BATCH);
                        batch.push(first);
                        while batch.len() < DEMOTE_BATCH {
                            match g.demote_q.try_pop() {
                                Some(n) => batch.push(n),
                                None => break,
                            }
                        }
                        g.process_victims(&batch);
                    }
                })
                .expect("spawn tier demoter")
        };
        Ok(Arc::new(TieredFeatureStore {
            fb,
            gpu: Some(gpu),
            demoter: Mutex::new(Some(worker)),
        }))
    }

    pub fn is_gpu(&self) -> bool {
        self.gpu.is_some()
    }

    /// The wrapped host buffer (stats, invariant checks, staging).
    pub fn buffer(&self) -> &Arc<FeatureBuffer> {
        &self.fb
    }

    /// Device-resident rows of the GPU tier (0 in host mode).
    pub fn gpu_capacity_rows(&self) -> usize {
        self.gpu.as_ref().map_or(0, |g| g.capacity)
    }

    /// Plan a batch across the tiers. GPU residents are referenced and
    /// aliased immediately (`fb.n_slots + slot`); the rest goes through the
    /// host buffer's planner unchanged, so `to_load`/`wait_*` only ever
    /// name host work. Repeated host hits promote, first-touch loads mark
    /// for admission bypass.
    pub fn begin_batch(&self, nodes: &[u32]) -> BatchPlan {
        let Some(gpu) = &self.gpu else {
            return self.fb.begin_batch(nodes);
        };
        gpu.drain_pending(&self.fb);

        let base = self.fb.n_slots as i32;
        let mut gpu_alias: Vec<i32> = Vec::with_capacity(nodes.len());
        let mut rest: Vec<u32> = Vec::new();
        let mut rest_freq: Vec<u32> = Vec::new();
        let mut spill_hits = 0u64;
        {
            let mut inner = gpu.inner.lock().unwrap();
            for &n in nodes {
                let f = {
                    let e = inner.freq.entry(n).or_insert(0);
                    *e += 1;
                    *e
                };
                match inner.map.get(&n).copied() {
                    Some(slot) => {
                        gpu.take_ref(slot);
                        inner.bypass_pending.remove(&n);
                        gpu_alias.push(base + slot as i32);
                        if (slot as usize) >= gpu.capacity {
                            spill_hits += 1;
                        }
                    }
                    None => {
                        if f >= 2 {
                            // Re-accessed: no longer a one-off cold seed.
                            inner.bypass_pending.remove(&n);
                        }
                        gpu_alias.push(-1);
                        rest.push(n);
                        rest_freq.push(f);
                    }
                }
            }
        }
        let n_gpu = (nodes.len() - rest.len()) as u64;
        if n_gpu > 0 {
            gpu.gpu_hits.fetch_add(n_gpu, Ordering::Relaxed);
            gpu.pcie_saved_bytes
                .fetch_add((n_gpu - spill_hits) * gpu.row_bytes as u64, Ordering::Relaxed);
        }
        if spill_hits > 0 {
            // UVM oversubscription: every access to an over-capacity row
            // pays a fault migration, charged as one burst per batch.
            gpu.oversub_faults.fetch_add(spill_hits, Ordering::Relaxed);
            gpu.pcie_tier_bytes
                .fetch_add(spill_hits * gpu.row_bytes as u64, Ordering::Relaxed);
            gpu.pcie.transfer_sync(spill_hits as usize * gpu.row_bytes);
        }

        let mut plan = self.fb.begin_batch(&rest);
        gpu.host_hits
            .fetch_add((rest.len() - plan.to_load.len()) as u64, Ordering::Relaxed);

        // Promotion: host hits past the frequency/degree threshold are
        // copied up. Loads and shared waits are skipped — their rows are
        // not valid yet; they promote on a later hit.
        let loading: HashSet<u32> = plan.to_load.iter().map(|&(n, _)| n).collect();
        let waiting: HashSet<u32> = plan.wait_list.iter().copied().collect();
        let mut promoted_bytes = 0usize;
        let mut row = vec![0f32; gpu.dim];
        let mut seen: HashSet<u32> = HashSet::new();
        for (i, &n) in rest.iter().enumerate() {
            if loading.contains(&n) || waiting.contains(&n) || !seen.insert(n) {
                continue;
            }
            if rest_freq[i] < gpu.threshold_for(n) {
                continue;
            }
            let alias = plan.aliases[i];
            if alias < 0 {
                continue;
            }
            // The plan holds a reference on the host slot, so the row is
            // stable; copy it out before taking the tier lock.
            self.fb.gather(std::slice::from_ref(&alias), &mut row);
            let mut inner = gpu.inner.lock().unwrap();
            if inner.map.contains_key(&n) {
                continue; // a peer batch promoted it meanwhile
            }
            let Some(slot) = gpu.alloc_slot(&mut inner) else {
                // Capacity pressure: feed the demoter and stop promoting
                // this batch (eviction stays off the critical path).
                gpu.sweep_victims(&mut inner);
                break;
            };
            // Exclusive ownership: the slot is unmapped and invalid.
            unsafe { gpu.arena.write_row(slot as usize, &row) };
            let gen = slot_state::generation(gpu.states.load(slot));
            gpu.states.reset(slot, 0, true, gen.wrapping_add(1));
            // Recently-used protection: a fresh promotion survives the next
            // clock pass instead of being the sweep's first victim.
            gpu.states.set_clock(slot);
            inner.slot_node[slot as usize] = n;
            inner.pinned[slot as usize] = false;
            inner.map.insert(n, slot);
            // The current batch keeps its host alias; the *host* row is
            // released back once it idles so the node ends up resident in
            // exactly one tier.
            inner.pending_host_evict.push(n);
            drop(inner);
            gpu.promotions.fetch_add(1, Ordering::Relaxed);
            promoted_bytes += gpu.row_bytes;
        }
        if promoted_bytes > 0 {
            gpu.pcie_tier_bytes.fetch_add(promoted_bytes as u64, Ordering::Relaxed);
            gpu.pcie.transfer_sync(promoted_bytes);
        }

        // Admission bypass: first-touch loads are one-off cold seeds until
        // proven otherwise — their host row is dropped once it idles past
        // one batch window without a second access.
        {
            let mut inner = gpu.inner.lock().unwrap();
            for &(n, _) in &plan.to_load {
                if inner.freq.get(&n).copied().unwrap_or(0) <= 1 {
                    inner.bypass_pending.entry(n).or_insert(0);
                }
            }
        }

        // Splice the GPU aliases back into batch order: host aliases are
        // consumed in `rest` order, which is the batch order of non-GPU
        // nodes.
        let mut merged = Vec::with_capacity(nodes.len());
        let mut host_it = plan.aliases.iter();
        for ga in &gpu_alias {
            merged.push(if *ga >= 0 {
                *ga
            } else {
                *host_it.next().expect("one host alias per non-GPU node")
            });
        }
        plan.aliases = merged;
        plan
    }

    /// Block until the plan's host-side rows are published (GPU rows are
    /// valid by construction).
    pub fn wait_plan(&self, plan: &BatchPlan) {
        self.fb.wait_plan(plan);
    }

    /// Gather rows for a (possibly mixed) alias vector into `out`
    /// (`aliases.len() × dim`). Negative aliases zero-fill, exactly like
    /// the host buffer.
    pub fn gather(&self, aliases: &[i32], out: &mut [f32]) {
        let Some(gpu) = &self.gpu else {
            return self.fb.gather(aliases, out);
        };
        let base = self.fb.n_slots as i32;
        if aliases.iter().all(|&a| a < base) {
            return self.fb.gather(aliases, out);
        }
        // Mask GPU aliases to -1 for the host gather (it zero-fills), then
        // overwrite those rows from the GPU arena.
        let masked: Vec<i32> = aliases.iter().map(|&a| if a >= base { -1 } else { a }).collect();
        self.fb.gather(&masked, out);
        let dim = gpu.dim;
        for (i, &a) in aliases.iter().enumerate() {
            if a >= base {
                let slot = (a - base) as u32;
                // Acquire pairs with the publishing SeqCst store.
                let w = gpu.states.load_acquire(slot);
                debug_assert!(slot_state::is_valid(w), "gather of unpublished tier slot");
                debug_assert!(slot_state::refs(w) > 0, "gather of unreferenced tier slot");
                unsafe { gpu.arena.read_row(slot as usize, &mut out[i * dim..(i + 1) * dim]) };
            }
        }
    }

    /// Release a batch's references across both tiers. Negative aliases
    /// are skipped, mirroring the host buffer.
    pub fn release_aliases(&self, aliases: &[i32]) {
        let Some(gpu) = &self.gpu else {
            return self.fb.release_aliases(aliases);
        };
        let base = self.fb.n_slots as i32;
        let mut any_gpu = false;
        for &a in aliases {
            if a >= base {
                any_gpu = true;
                let prev = gpu.states.sub_ref((a - base) as u32);
                debug_assert!(slot_state::refs(prev) > 0, "tier release without reference");
            }
        }
        if !any_gpu {
            return self.fb.release_aliases(aliases);
        }
        let masked: Vec<i32> = aliases.iter().map(|&a| if a >= base { -1 } else { a }).collect();
        self.fb.release_aliases(&masked);
    }

    /// Evict idle host rows (failed-load recovery path); the GPU tier is
    /// untouched — tier rows leave only through the demoter.
    pub fn evict_if_idle(&self, nodes: &[u32]) -> usize {
        self.fb.evict_if_idle(nodes)
    }

    /// Pin one packed-layout hot row directly into the GPU tier
    /// (`attach_layout`): pinned rows are device-resident for the lifetime
    /// of the store and never demoted. Returns `false` when the
    /// device-resident region is full — the caller overflows to the host
    /// pinning path. Callers charge the PCIe upload in one burst via
    /// [`TieredFeatureStore::charge_tier_upload`].
    pub fn pin_gpu_row(&self, node: u32, le_bytes: &[u8]) -> bool {
        let Some(gpu) = &self.gpu else {
            return false;
        };
        debug_assert!(le_bytes.len() >= gpu.row_bytes, "pinned row too short");
        let mut inner = gpu.inner.lock().unwrap();
        if inner.map.contains_key(&node) {
            return true;
        }
        // Pins never spill: the oversubscription region is for dynamic
        // admissions only.
        let Some(slot) = inner.free.pop() else {
            return false;
        };
        unsafe { gpu.arena.write_row_le(slot as usize, le_bytes) };
        let gen = slot_state::generation(gpu.states.load(slot));
        // A permanent reference backs up the pinned flag: the clock sweep
        // skips referenced slots without even consulting `pinned`.
        gpu.states.reset(slot, 1, true, gen.wrapping_add(1));
        inner.slot_node[slot as usize] = node;
        inner.pinned[slot as usize] = true;
        inner.map.insert(node, slot);
        true
    }

    /// Charge one batched host→device upload (pinned-layout attach) to the
    /// PCIe model and the tier's transfer counter.
    pub fn charge_tier_upload(&self, bytes: usize) {
        if let Some(gpu) = &self.gpu {
            if bytes > 0 {
                gpu.pcie_tier_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
                gpu.pcie.transfer_sync(bytes);
            }
        }
    }

    /// Synchronously drain the demotion queue (tests and quiesce — the
    /// background demoter normally does this).
    pub fn flush_demotions(&self) {
        if let Some(gpu) = &self.gpu {
            let mut batch = Vec::new();
            while let Some(n) = gpu.demote_q.try_pop() {
                batch.push(n);
            }
            gpu.process_victims(&batch);
        }
    }

    /// Settle all deferred bookkeeping: demotions and pending host-side
    /// evictions. Call with no batch in flight (end of epoch, tests).
    pub fn quiesce(&self) {
        if let Some(gpu) = &self.gpu {
            self.flush_demotions();
            gpu.drain_pending(&self.fb);
        }
    }

    /// Monotonic tier counters (all zero in host mode).
    pub fn snapshot(&self) -> TierSnapshot {
        self.gpu.as_ref().map_or(TierSnapshot::default(), |g| g.snapshot())
    }

    /// Structural invariants of both tiers (tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        self.fb.check_invariants()?;
        let Some(gpu) = &self.gpu else {
            return Ok(());
        };
        let inner = gpu.inner.lock().unwrap();
        let accounted = inner.map.len() + inner.free.len() + inner.spill_free.len();
        if accounted != inner.spill_next {
            return Err(format!(
                "tier slots leaked: {} mapped + {} free + {} spill-free != {} activated",
                inner.map.len(),
                inner.free.len(),
                inner.spill_free.len(),
                inner.spill_next
            ));
        }
        for (&n, &s) in &inner.map {
            if inner.slot_node[s as usize] != n {
                return Err(format!("tier map {n}->{s} but slot_node says {}", {
                    inner.slot_node[s as usize]
                }));
            }
            if !slot_state::is_valid(gpu.states.load(s)) {
                return Err(format!("mapped tier slot {s} is not valid"));
            }
        }
        for &s in inner.free.iter().chain(inner.spill_free.iter()) {
            if inner.slot_node[s as usize] != NO_NODE {
                return Err(format!("free tier slot {s} still has a tenant"));
            }
            let w = gpu.states.load(s);
            if slot_state::is_valid(w) || slot_state::refs(w) != 0 {
                return Err(format!("free tier slot {s} has live state {w:#x}"));
            }
        }
        Ok(())
    }

    /// Tier exclusivity: after [`TieredFeatureStore::quiesce`], no node may
    /// be resident in both tiers (the property-test gate).
    pub fn check_exclusive(&self) -> Result<(), String> {
        let Some(gpu) = &self.gpu else {
            return Ok(());
        };
        let inner = gpu.inner.lock().unwrap();
        for &n in inner.map.keys() {
            if self.fb.is_resident(n) {
                return Err(format!("node {n} resident in both tiers"));
            }
        }
        Ok(())
    }
}

impl Drop for TieredFeatureStore {
    fn drop(&mut self) {
        if let Some(gpu) = &self.gpu {
            gpu.demote_q.close();
        }
        if let Some(h) = self.demoter.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Clock;
    use crate::storage::mem::HostMemory;
    use crate::storage::pcie::{Pcie, PcieConfig};

    const DIM: usize = 4;
    const ROW_BYTES: u64 = (DIM * 4) as u64;

    fn fb(slots: usize) -> Arc<FeatureBuffer> {
        let host = HostMemory::new(1 << 30);
        Arc::new(FeatureBuffer::in_host(&host, slots, DIM).unwrap())
    }

    fn pcie() -> Arc<Pcie> {
        // Effectively free transfers: unit tests assert placement, not time.
        Pcie::new(
            PcieConfig { bandwidth: 1e12, latency: std::time::Duration::ZERO, engines: 1 },
            Clock::new(1.0),
        )
    }

    fn gpu_store(fb_slots: usize, gpu_rows: u64, policy: TierPolicy) -> Arc<TieredFeatureStore> {
        let dev = DeviceMemory::new(1 << 30);
        TieredFeatureStore::gpu(fb(fb_slots), &dev, pcie(), gpu_rows * ROW_BYTES, policy)
            .unwrap()
    }

    /// Run one batch end to end: plan, publish any loads, wait, gather,
    /// release. Returns the plan's aliases.
    fn run_batch(store: &TieredFeatureStore, nodes: &[u32]) -> Vec<i32> {
        let plan = store.begin_batch(nodes);
        for &(node, slot) in &plan.to_load {
            let row: Vec<f32> = (0..DIM).map(|d| node as f32 + d as f32 / 10.0).collect();
            store.buffer().publish(node, slot, &row);
        }
        store.wait_plan(&plan);
        let mut out = vec![0f32; nodes.len() * DIM];
        store.gather(&plan.aliases, &mut out);
        for (i, &n) in nodes.iter().enumerate() {
            assert_eq!(out[i * DIM], n as f32, "row content for node {n}");
        }
        let aliases = plan.aliases.clone();
        store.release_aliases(&plan.aliases);
        aliases
    }

    #[test]
    fn host_mode_is_pure_delegation() {
        let store = TieredFeatureStore::host(fb(8));
        assert!(!store.is_gpu());
        run_batch(&store, &[1, 2, 3]);
        assert_eq!(store.snapshot(), TierSnapshot::default());
        let (hits, _, _, loads) = store.buffer().stats();
        assert_eq!(loads, 3);
        assert_eq!(hits, 0);
        run_batch(&store, &[1, 2, 3]);
        let (hits, _, _, loads) = store.buffer().stats();
        assert_eq!((hits, loads), (3, 3), "host mode charges exactly like the raw buffer");
        store.check_invariants().unwrap();
    }

    #[test]
    fn promotion_needs_frequency_threshold() {
        let store = gpu_store(16, 8, TierPolicy::default());
        // Access 1: load (freq 1). Access 2: host hit at freq 2 → promote.
        run_batch(&store, &[5]);
        assert_eq!(store.snapshot().promotions, 0, "first touch must not promote");
        run_batch(&store, &[5]);
        let snap = store.snapshot();
        assert_eq!(snap.promotions, 1, "second touch (host hit) promotes");
        assert_eq!(snap.gpu_hits, 0);
        // Access 3: GPU hit, saving one row transfer.
        let aliases = run_batch(&store, &[5]);
        let snap = store.snapshot();
        assert_eq!(snap.gpu_hits, 1);
        assert_eq!(snap.pcie_saved_bytes, ROW_BYTES);
        assert!(aliases[0] >= store.buffer().n_slots as i32, "alias must be GPU-range");
        // Exclusivity: once quiesced, the host copy is gone.
        store.quiesce();
        store.check_exclusive().unwrap();
        store.check_invariants().unwrap();
    }

    #[test]
    fn degree_prior_lowers_threshold() {
        // Graph with avg degree 2; node 0 has degree 6 (above average) and
        // node 1 degree 1 (below).
        let indptr = Arc::new(vec![0u64, 6, 7, 8, 8]);
        let policy = TierPolicy { indptr: Some(indptr), ..TierPolicy::default() };
        let store = gpu_store(16, 8, policy);
        run_batch(&store, &[0, 1]); // both load (freq 1)
        run_batch(&store, &[0, 1]); // host hits at freq 2: both ≥ threshold
        let snap = store.snapshot();
        assert_eq!(snap.promotions, 2);
        // With a raised base threshold the degree prior separates the two:
        // the high-degree node promotes one hit earlier.
        let indptr = Arc::new(vec![0u64, 6, 7, 8, 8]);
        let policy =
            TierPolicy { promote_threshold: 3, indptr: Some(indptr), ..TierPolicy::default() };
        let store = gpu_store(16, 8, policy);
        run_batch(&store, &[0, 1]); // load, freq 1
        run_batch(&store, &[0, 1]); // freq 2: node 0 (thresh 2) promotes, node 1 (thresh 3) not
        let snap = store.snapshot();
        assert_eq!(snap.promotions, 1, "only the high-degree node promotes at freq 2");
        run_batch(&store, &[0, 1]); // freq 3: node 1 reaches its threshold
        assert_eq!(store.snapshot().promotions, 2);
    }

    #[test]
    fn batched_demotion_preserves_queue_order() {
        let store = gpu_store(32, 2, TierPolicy::default());
        let gpu = store.gpu.as_ref().unwrap();
        // Fill the 2-row tier with nodes 10 and 11.
        for _ in 0..2 {
            run_batch(&store, &[10, 11]);
        }
        assert_eq!(store.snapshot().promotions, 2);
        // A third hot node finds the tier full: the sweep clears clock bits
        // first (second chance), so force two allocation failures.
        for _ in 0..3 {
            run_batch(&store, &[12, 13]);
        }
        store.quiesce();
        // Victims were enqueued and demoted in clock order: slot 0's
        // tenant (node 10) before slot 1's (node 11).
        let log = gpu.inner.lock().unwrap().demote_log.clone();
        assert!(!log.is_empty(), "capacity pressure must demote");
        let p10 = log.iter().position(|&n| n == 10);
        let p11 = log.iter().position(|&n| n == 11);
        if let (Some(a), Some(b)) = (p10, p11) {
            assert!(a < b, "demotion preserves clock/FIFO order: {log:?}");
        }
        store.check_invariants().unwrap();
        store.check_exclusive().unwrap();
    }

    #[test]
    fn admission_bypass_drops_one_off_seeds() {
        let store = gpu_store(64, 8, TierPolicy::default());
        // Nodes 100..104 are touched exactly once (cold seeds); node 7 is
        // touched repeatedly (hot).
        run_batch(&store, &[7, 100, 101, 102, 103]);
        run_batch(&store, &[7]); // freq-2 host hit: promoted + rescued from bypass
        let aliases = run_batch(&store, &[7]); // GPU hit; ripe seeds dropped
        store.quiesce();
        let snap = store.snapshot();
        assert!(snap.bypassed >= 4, "one-off seeds must be dropped, got {}", snap.bypassed);
        assert_eq!(snap.promotions, 1);
        for n in 100..104 {
            assert!(!store.buffer().is_resident(n), "cold seed {n} still occupies the buffer");
        }
        // The hot node survives — in the GPU tier, not the host buffer.
        assert!(aliases[0] >= store.buffer().n_slots as i32);
        store.check_invariants().unwrap();
        store.check_exclusive().unwrap();
    }

    #[test]
    fn repeat_access_rescues_a_bypass_candidate() {
        let store = gpu_store(64, 8, TierPolicy::default());
        run_batch(&store, &[42]); // cold load → bypass candidate (age 0)
        run_batch(&store, &[42]); // re-accessed before ripening: rescued + promoted
        store.quiesce();
        store.quiesce();
        let snap = store.snapshot();
        assert_eq!(snap.bypassed, 0, "re-accessed node must not count as bypassed");
        assert_eq!(snap.promotions, 1);
    }

    #[test]
    fn oversub_spills_past_capacity_and_charges_faults() {
        let policy = TierPolicy { oversub: true, ..TierPolicy::default() };
        let store = gpu_store(64, 2, policy);
        // Promote 4 hot nodes into a 2-row tier: the extra two land in the
        // spill region instead of evicting.
        for _ in 0..2 {
            run_batch(&store, &[1, 2, 3, 4]);
        }
        let snap = store.snapshot();
        assert_eq!(snap.promotions, 4, "oversubscription admits past capacity");
        assert_eq!(snap.demotions, 0, "the ablation never demotes");
        // Hitting all four now faults on the two over-capacity rows.
        run_batch(&store, &[1, 2, 3, 4]);
        let snap = store.snapshot();
        assert_eq!(snap.gpu_hits, 4);
        assert_eq!(snap.oversub_faults, 2, "spill-region accesses pay fault migrations");
        assert!(snap.pcie_tier_bytes >= 4 * ROW_BYTES + 2 * ROW_BYTES);
        store.check_invariants().unwrap();
    }

    #[test]
    fn explicit_tiering_never_spills() {
        let store = gpu_store(64, 2, TierPolicy::default());
        for _ in 0..3 {
            run_batch(&store, &[1, 2, 3, 4]);
        }
        store.quiesce();
        let snap = store.snapshot();
        assert_eq!(snap.oversub_faults, 0);
        let gpu = store.gpu.as_ref().unwrap();
        assert_eq!(gpu.inner.lock().unwrap().spill_next, gpu.capacity, "no spill slot used");
    }

    #[test]
    fn pinned_rows_are_never_demoted() {
        let store = gpu_store(64, 2, TierPolicy::default());
        // Row bytes match what run_batch expects to gather back.
        let row90: Vec<u8> =
            (0..DIM).flat_map(|d| (90.0f32 + d as f32 / 10.0).to_le_bytes()).collect();
        assert!(store.pin_gpu_row(90, &row90));
        store.charge_tier_upload(ROW_BYTES as usize);
        // Heavy churn through the remaining single slot.
        for n in 0..8u32 {
            for _ in 0..3 {
                run_batch(&store, &[n]);
            }
        }
        store.quiesce();
        let aliases = run_batch(&store, &[90]);
        assert!(aliases[0] >= store.buffer().n_slots as i32, "pinned row stays GPU-resident");
        assert!(store.snapshot().pcie_tier_bytes >= ROW_BYTES);
        store.check_invariants().unwrap();
    }

    #[test]
    fn pin_overflows_to_host_when_full() {
        let store = gpu_store(64, 2, TierPolicy::default());
        let row = |n: u32| -> Vec<u8> {
            let mut v = Vec::new();
            for d in 0..DIM {
                v.extend_from_slice(&(n as f32 + d as f32 / 10.0).to_le_bytes());
            }
            v
        };
        assert!(store.pin_gpu_row(1, &row(1)));
        assert!(store.pin_gpu_row(2, &row(2)));
        assert!(!store.pin_gpu_row(3, &row(3)), "full device region refuses the pin");
    }

    #[test]
    fn residency_is_exclusive_and_refs_balance_after_churn() {
        // Property test: random-ish churn with duplicates across a small
        // two-tier stack, then quiesce — every node in at most one tier,
        // no leaked references, structural invariants hold.
        let store = gpu_store(32, 4, TierPolicy::default());
        let mut state = 0x9E3779B97F4A7C15u64;
        let mut step = || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u32
        };
        for _ in 0..300 {
            let len = 1 + (step() % 6) as usize;
            let nodes: Vec<u32> = (0..len).map(|_| step() % 24).collect();
            run_batch(&store, &nodes);
        }
        store.quiesce();
        store.quiesce(); // second pass settles evictions deferred by refs
        store.check_invariants().unwrap();
        store.check_exclusive().unwrap();
        // Zero leaked refs: every mapped GPU slot is back to its baseline
        // reference count (0 dynamic, 1 pinned).
        let gpu = store.gpu.as_ref().unwrap();
        let inner = gpu.inner.lock().unwrap();
        for (&n, &s) in &inner.map {
            let w = gpu.states.load(s);
            let baseline = if inner.pinned[s as usize] { 1 } else { 0 };
            assert_eq!(
                slot_state::refs(w),
                baseline,
                "node {n} slot {s} leaked references after churn"
            );
        }
    }

    #[test]
    fn tier_kind_parses() {
        assert_eq!(TierKind::by_name("host"), Some(TierKind::Host));
        assert_eq!(TierKind::by_name("GPU"), Some(TierKind::Gpu));
        assert_eq!(TierKind::by_name("uvm"), None);
        assert_eq!(TierKind::default(), TierKind::Host);
    }

    #[test]
    fn snapshot_since_and_merge() {
        let a = TierSnapshot { gpu_hits: 10, host_hits: 5, ..TierSnapshot::default() };
        let b = TierSnapshot { gpu_hits: 25, host_hits: 9, ..TierSnapshot::default() };
        let d = b.since(&a);
        assert_eq!((d.gpu_hits, d.host_hits), (15, 4));
        let mut m = a;
        m.merge(&d);
        assert_eq!(m, b);
        assert!((b.gpu_hit_fraction() - 25.0 / 34.0).abs() < 1e-12);
        assert_eq!(TierSnapshot::default().gpu_hit_fraction(), 0.0);
    }
}
