//! PyG+ baseline (paper §2/§3): disk-based training by memory-mapping both
//! topological and feature data and letting the OS page cache carry
//! everything.
//!
//! Mechanisms reproduced:
//! * loader workers each handle a whole mini-batch: sample (mmap topology
//!   reads) then extract (mmap *feature* reads — synchronous, through the
//!   shared page cache, where they evict topology pages: the D1 memory
//!   contention), then a synchronous H2D transfer;
//! * one trainer consumes prepared batches from a small prefetch queue;
//! * no private caches, no async I/O: every miss stalls the worker (the D2
//!   I/O congestion).

use super::common::TrainingSystem;
use crate::config::{Machine, TrainConfig};
use crate::graph::Dataset;
use crate::metrics::state::{self, Role, State};
use crate::pipeline::EpochStats;
use crate::sample::{EpochPlan, PaddedSubgraph, Sampler};
use crate::sim::queue::BoundedQueue;
use crate::sim::Stopwatch;
use crate::storage::IoBackend as _;
use crate::train::{TrainStats, TrainStep};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

pub struct PygPlus {
    machine: Arc<Machine>,
    ds: Arc<Dataset>,
    cfg: TrainConfig,
    caps: Vec<usize>,
    trainer: Mutex<Box<dyn TrainStep>>,
    /// Loader workers (paper: DataLoader workers; sample+extract each).
    workers: usize,
}

impl PygPlus {
    pub fn new(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: TrainConfig,
        trainer: Box<dyn TrainStep>,
    ) -> Self {
        let caps = trainer.caps().to_vec();
        PygPlus {
            workers: cfg.samplers + cfg.extractors, // same thread budget as GNNDrive
            machine: machine.clone(),
            ds: ds.clone(),
            cfg,
            caps,
            trainer: Mutex::new(trainer),
        }
    }

    /// Synchronous mmap-style feature extraction: one buffered read per
    /// node row, through the shared page cache.
    fn extract_sync(&self, padded: &PaddedSubgraph, out: &mut [f32]) {
        let dim = self.ds.spec.dim;
        let row_bytes = self.ds.features.row_bytes() as usize;
        let mut buf = vec![0u8; row_bytes];
        for (i, &node) in padded.nodes[..padded.real_nodes].iter().enumerate() {
            self.machine.backend.read_buffered(
                &self.ds.features.file,
                self.ds.features.row_offset(node as u64),
                &mut buf,
            );
            for (j, b) in buf.chunks_exact(4).take(dim).enumerate() {
                out[i * dim + j] = f32::from_le_bytes(b.try_into().unwrap());
            }
        }
        out[padded.real_nodes * dim..].fill(0.0);
    }
}

struct Prepared {
    padded: Arc<PaddedSubgraph>,
    feats: Vec<f32>,
}

impl TrainingSystem for PygPlus {
    fn name(&self) -> &'static str {
        "PyG+"
    }

    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats> {
        let clock = &self.machine.clock;
        let plan = EpochPlan::new(
            &self.ds.train_ids,
            self.cfg.batch_size,
            self.cfg.seed,
            epoch,
            self.cfg.batches_per_epoch,
        );
        // Prefetch queue between loader workers and the trainer
        // (DataLoader's prefetch_factor ≈ 2 × workers is capped small).
        let ready = BoundedQueue::<Prepared>::new(4);
        let sample_ns = AtomicU64::new(0);
        let extract_ns = AtomicU64::new(0);
        let train_ns = AtomicU64::new(0);
        let workers_left = AtomicUsize::new(self.workers);
        let train_stats = Mutex::new(TrainStats::default());
        let batches_done = AtomicUsize::new(0);
        let dim = self.ds.spec.dim;
        let cap_l = *self.caps.last().unwrap();

        let watch = Stopwatch::start(clock);
        let io_snap = crate::storage::EpochIoSnapshot::start(self.machine.backend.as_ref());

        std::thread::scope(|s| {
            for _ in 0..self.workers {
                let plan = &plan;
                let ready = &ready;
                let sample_ns = &sample_ns;
                let extract_ns = &extract_ns;
                let workers_left = &workers_left;
                let this = &*self;
                let sampler = Sampler::new(self.cfg.fanouts.clone(), self.cfg.seed ^ (epoch << 8));
                s.spawn(move || {
                    state::register(Role::Sampler);
                    while let Some((batch_id, seeds)) = plan.claim() {
                        let sw = Stopwatch::start(clock);
                        let sub =
                            sampler.sample_batch(&this.ds, this.machine.backend.as_ref(), batch_id, seeds);
                        let padded = Arc::new(sub.pad(&this.caps, &this.cfg.fanouts));
                        sample_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);

                        let sw = Stopwatch::start(clock);
                        let mut feats = vec![0f32; cap_l * dim];
                        this.extract_sync(&padded, &mut feats);
                        // Synchronous H2D transfer of the whole batch.
                        this.machine.pcie.transfer_sync(padded.real_nodes * dim * 4);
                        extract_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);

                        let _idle = state::enter(State::Idle);
                        if ready.push(Prepared { padded, feats }).is_err() {
                            break;
                        }
                    }
                    if workers_left.fetch_sub(1, Ordering::SeqCst) == 1 {
                        ready.close();
                    }
                    state::deregister();
                });
            }

            // Trainer.
            {
                let ready = &ready;
                let train_ns = &train_ns;
                let train_stats = &train_stats;
                let batches_done = &batches_done;
                let this = &*self;
                s.spawn(move || {
                    state::register(Role::Trainer);
                    let mut trainer = this.trainer.lock().unwrap();
                    loop {
                        let item = {
                            let _idle = state::enter(State::Idle);
                            match ready.pop() {
                                Ok(i) => i,
                                Err(_) => break,
                            }
                        };
                        let sw = Stopwatch::start(clock);
                        let r = trainer.step(&item.padded, &item.feats);
                        train_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        train_stats.lock().unwrap().push(&r);
                        batches_done.fetch_add(1, Ordering::Relaxed);
                    }
                    state::deregister();
                });
            }
        });

        let io = io_snap.totals(self.machine.backend.as_ref());
        Ok(EpochStats {
            epoch_time: watch.elapsed(),
            prep_time: Duration::ZERO,
            sample_time: Duration::from_nanos(sample_ns.into_inner()),
            extract_time: Duration::from_nanos(extract_ns.into_inner()),
            train_time: Duration::from_nanos(train_ns.into_inner()),
            batches: batches_done.into_inner(),
            train: train_stats.into_inner().unwrap(),
            reorder_inversions: 0, // PyG+ trains strictly in order
            ssd_read_bytes: io.read_bytes,
            ssd_read_requests: io.reads,
            extract_hist: Default::default(), // per-batch tail tracked for GNNDrive only
            align_overhead_bytes: io.align_overhead_bytes,
            truncated_edges: 0,
            io_retries: io.io_retries,
            io_failures: io.io_failures,
            direct_fallbacks: io.direct_fallbacks,
            dropped_rows: 0,
            ..Default::default()
        })
    }

    fn run_sample_only(&mut self, epoch: u64) -> Duration {
        let clock = &self.machine.clock;
        let plan = EpochPlan::new(
            &self.ds.train_ids,
            self.cfg.batch_size,
            self.cfg.seed,
            epoch,
            self.cfg.batches_per_epoch,
        );
        let sample_ns = AtomicU64::new(0);
        std::thread::scope(|s| {
            for _ in 0..self.workers {
                let plan = &plan;
                let sample_ns = &sample_ns;
                let this = &*self;
                let sampler = Sampler::new(self.cfg.fanouts.clone(), self.cfg.seed ^ (epoch << 8));
                s.spawn(move || {
                    state::register(Role::Sampler);
                    while let Some((batch_id, seeds)) = plan.claim() {
                        let sw = Stopwatch::start(clock);
                        let sub =
                            sampler.sample_batch(&this.ds, this.machine.backend.as_ref(), batch_id, seeds);
                        std::hint::black_box(&sub);
                        sample_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                    }
                    state::deregister();
                });
            }
        });
        Duration::from_nanos(sample_ns.into_inner())
    }
}
