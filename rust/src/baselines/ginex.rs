//! Ginex baseline (Park et al., VLDB '22; paper §2/§3).
//!
//! Mechanisms reproduced:
//! * two dedicated in-memory caches carved out of host memory (≥85 % of it,
//!   per the paper's Fig 9 setup): a static **neighbor cache** holding the
//!   hottest adjacency lists for sampling, and a **feature cache** with a
//!   Belady-guided replacement policy;
//! * **superbatch** processing: sample every mini-batch of the superbatch up
//!   front, *write the sampled node lists to SSD*, read them back in an
//!   **inspect** pass that computes next-use times, then synchronously
//!   initialize the feature cache with the hottest rows (the I/O-congestion
//!   spike of Fig 3b);
//! * per-batch extraction hits the feature cache and pays synchronous
//!   multi-threaded reads for misses; training is strictly in order.

use super::common::TrainingSystem;
use crate::config::{Machine, TrainConfig};
use crate::graph::Dataset;
use crate::metrics::state::{self, Role};
use crate::pipeline::EpochStats;
use crate::sample::{EpochPlan, PaddedSubgraph, Sampler};
use crate::sim::Stopwatch;
use crate::storage::{IoBackend as _, Reservation};
use crate::train::{TrainStats, TrainStep};
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::cmp::Reverse;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Host-memory fractions for the two caches (paper: together ≥85 %).
const NEIGHBOR_CACHE_FRAC: f64 = 0.17;
const FEATURE_CACHE_FRAC: f64 = 0.68;
/// Threads for synchronous I/O phases (paper: > 2 × cores).
const IO_THREADS: usize = 8;

pub struct Ginex {
    machine: Arc<Machine>,
    ds: Arc<Dataset>,
    cfg: TrainConfig,
    caps: Vec<usize>,
    trainer: Mutex<Box<dyn TrainStep>>,
    /// Static neighbor cache: hottest nodes by degree.
    topo_cache: Arc<HashSet<u32>>,
    _nc_res: Reservation,
    fc_rows: usize,
    _fc_res: Reservation,
}

impl Ginex {
    pub fn new(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: TrainConfig,
        trainer: Box<dyn TrainStep>,
    ) -> anyhow::Result<Self> {
        let caps = trainer.caps().to_vec();
        let host = machine.host.capacity() as f64;
        let nc_bytes = (host * NEIGHBOR_CACHE_FRAC) as u64;
        let fc_bytes = (host * FEATURE_CACHE_FRAC) as u64;
        let _nc_res = machine.host.reserve("ginex neighbor cache", nc_bytes)?;
        let _fc_res = machine.host.reserve("ginex feature cache", fc_bytes)?;
        let fc_rows = (fc_bytes / ds.features.row_bytes()).max(1) as usize;

        // Fill the neighbor cache greedily by degree (one-time, charged as
        // a sequential scan of the degree array — negligible next to data).
        let mut order: Vec<u32> = (0..ds.graph.nodes).collect();
        order.sort_unstable_by_key(|&v| Reverse(ds.graph.degree(v)));
        let mut used = 0u64;
        let mut cached = HashSet::new();
        for v in order {
            let cost = ds.graph.degree(v) * 4 + 16;
            if used + cost > nc_bytes {
                break;
            }
            used += cost;
            cached.insert(v);
        }
        Ok(Ginex {
            machine: machine.clone(),
            ds: ds.clone(),
            cfg,
            caps,
            trainer: Mutex::new(trainer),
            topo_cache: Arc::new(cached),
            _nc_res,
            fc_rows,
            _fc_res,
        })
    }

    fn sampler(&self, epoch: u64) -> Sampler {
        Sampler::new(self.cfg.fanouts.clone(), self.cfg.seed ^ (epoch << 8))
            .with_topo_cache(self.topo_cache.clone())
    }

    /// Superbatch sampling: sample everything, dump node lists to SSD.
    /// Returns padded batches + summed sampling time.
    fn sample_superbatch(
        &self,
        epoch: u64,
        plan: &EpochPlan,
    ) -> (Vec<Arc<PaddedSubgraph>>, Duration) {
        let clock = &self.machine.clock;
        let sample_ns = AtomicU64::new(0);
        let out: Mutex<Vec<(u64, Arc<PaddedSubgraph>)>> = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..self.cfg.samplers {
                let sampler = self.sampler(epoch);
                let sample_ns = &sample_ns;
                let out = &out;
                let this = &*self;
                s.spawn(move || {
                    state::register(Role::Sampler);
                    while let Some((batch_id, seeds)) = plan.claim() {
                        let sw = Stopwatch::start(clock);
                        let sub = sampler.sample_batch(
                            &this.ds,
                            this.machine.backend.as_ref(),
                            batch_id,
                            seeds,
                        );
                        // Ginex stores sampling results to SSD per
                        // superbatch (extra write I/O on the sample path).
                        this.machine.backend.charge_write(sub.nodes.len() * 4);
                        let padded = Arc::new(sub.pad(&this.caps, &this.cfg.fanouts));
                        sample_ns.fetch_add(sw.elapsed().as_nanos() as u64, Ordering::Relaxed);
                        out.lock().unwrap().push((batch_id, padded));
                    }
                    state::deregister();
                });
            }
        });
        let mut batches = out.into_inner().unwrap();
        batches.sort_by_key(|(id, _)| *id); // Ginex trains in order
        (
            batches.into_iter().map(|(_, b)| b).collect(),
            Duration::from_nanos(sample_ns.into_inner()),
        )
    }

    /// Inspect pass: read the dumped sample lists back and compute per-node
    /// occurrence queues (the Belady schedule). Charged: SSD reads of the
    /// dumped lists + a host reservation for the schedule itself.
    fn inspect(
        &self,
        batches: &[Arc<PaddedSubgraph>],
    ) -> anyhow::Result<(HashMap<u32, VecDeque<usize>>, Reservation)> {
        let mut total_ids = 0usize;
        for b in batches {
            total_ids += b.real_nodes;
            self.machine.backend.charge_read(b.real_nodes * 4);
        }
        // ~16 B/occurrence of workspace, the OOM lever at small memory.
        let res = self
            .machine
            .host
            .reserve("ginex inspect workspace", (total_ids * 16) as u64)?;
        let mut occ: HashMap<u32, VecDeque<usize>> = HashMap::new();
        for (i, b) in batches.iter().enumerate() {
            for &v in &b.nodes[..b.real_nodes] {
                occ.entry(v).or_default().push_back(i);
            }
        }
        Ok((occ, res))
    }

    /// Synchronously load `rows` feature rows with IO_THREADS workers
    /// (cache init + per-batch misses).
    fn sync_load_rows(&self, rows: &[u32]) {
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..IO_THREADS.min(rows.len().max(1)) {
                let cursor = &cursor;
                let this = &*self;
                s.spawn(move || {
                    state::register(Role::IoWorker);
                    let row_bytes = this.ds.features.row_bytes() as usize;
                    let mut buf = vec![0u8; row_bytes];
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= rows.len() {
                            break;
                        }
                        this.machine.backend.read_direct(
                            &this.ds.features.file,
                            this.ds.features.row_offset(rows[i] as u64),
                            &mut buf,
                        );
                    }
                    state::deregister();
                });
            }
        });
    }
}

/// Belady-guided feature cache state for one superbatch.
struct FeatureCache {
    rows: usize,
    resident: HashSet<u32>,
    /// Max-heap on next use; stale entries skipped lazily.
    heap: BinaryHeap<(usize, u32)>,
}

impl FeatureCache {
    fn next_use(occ: &HashMap<u32, VecDeque<usize>>, v: u32, after: usize) -> usize {
        occ.get(&v)
            .and_then(|q| q.iter().find(|&&b| b >= after))
            .copied()
            .unwrap_or(usize::MAX)
    }

    /// Returns true on hit; on miss inserts v (evicting the entry with the
    /// farthest next use when full).
    fn access(&mut self, occ: &HashMap<u32, VecDeque<usize>>, v: u32, batch: usize) -> bool {
        if self.resident.contains(&v) {
            self.heap.push((Self::next_use(occ, v, batch + 1), v));
            return true;
        }
        while self.resident.len() >= self.rows {
            match self.heap.pop() {
                Some((_, victim)) => {
                    // Lazily skip stale heap entries.
                    if self.resident.remove(&victim) {
                        continue;
                    }
                }
                None => {
                    // Heap drained but residents remain (all stale):
                    // rebuild by evicting arbitrarily.
                    let any = *self.resident.iter().next().unwrap();
                    self.resident.remove(&any);
                }
            }
        }
        self.resident.insert(v);
        self.heap.push((Self::next_use(occ, v, batch + 1), v));
        false
    }
}

impl TrainingSystem for Ginex {
    fn name(&self) -> &'static str {
        "Ginex"
    }

    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats> {
        let clock = &self.machine.clock;
        let plan = EpochPlan::new(
            &self.ds.train_ids,
            self.cfg.batch_size,
            self.cfg.seed,
            epoch,
            self.cfg.batches_per_epoch,
        );
        let watch = Stopwatch::start(clock);
        let io_snap = crate::storage::EpochIoSnapshot::start(self.machine.backend.as_ref());

        // Phase 1+2: superbatch sampling + inspect.
        let (batches, sample_time) = self.sample_superbatch(epoch, &plan);
        let prep_watch = Stopwatch::start(clock);
        let (occ, _inspect_res) = self.inspect(&batches)?;

        // Phase 3: synchronous feature-cache initialization with the rows
        // used soonest (the congestion spike).
        let mut hottest: Vec<(usize, u32)> = occ
            .iter()
            .map(|(&v, q)| (*q.front().unwrap_or(&usize::MAX), v))
            .collect();
        hottest.sort_unstable();
        let init_rows: Vec<u32> =
            hottest.iter().take(self.fc_rows).map(|&(_, v)| v).collect();
        self.sync_load_rows(&init_rows);
        let mut fc = FeatureCache {
            rows: self.fc_rows,
            resident: init_rows.iter().copied().collect(),
            heap: BinaryHeap::new(),
        };
        for &v in &init_rows {
            fc.heap.push((FeatureCache::next_use(&occ, v, 0), v));
        }
        let prep_time = prep_watch.elapsed();

        // Phase 4: per-batch extract (cache + sync misses) → transfer → train.
        let mut extract_time = Duration::ZERO;
        let mut train_time = Duration::ZERO;
        let mut stats = TrainStats::default();
        let mut trainer = self.trainer.lock().unwrap();
        let dim = self.ds.spec.dim;
        let cap_l = *self.caps.last().unwrap();
        let mut feats = vec![0f32; cap_l * dim];
        for (bi, padded) in batches.iter().enumerate() {
            let sw = Stopwatch::start(clock);
            let mut misses = Vec::new();
            for &v in &padded.nodes[..padded.real_nodes] {
                if !fc.access(&occ, v, bi) {
                    misses.push(v);
                }
            }
            self.sync_load_rows(&misses);
            // Fill the feature block from the oracle generator (cache hits
            // are host-memory copies; data correctness is preserved).
            let mut row = vec![0u8; dim * 4];
            for (i, &v) in padded.nodes[..padded.real_nodes].iter().enumerate() {
                self.ds.feature_gen.fill_row(v as u64, &mut row);
                for (j, b) in row.chunks_exact(4).enumerate() {
                    feats[i * dim + j] = f32::from_le_bytes(b.try_into().unwrap());
                }
            }
            self.machine.pcie.transfer_sync(padded.real_nodes * dim * 4);
            extract_time += sw.elapsed();

            let sw = Stopwatch::start(clock);
            let r = trainer.step(padded, &feats);
            train_time += sw.elapsed();
            stats.push(&r);
        }

        let io = io_snap.totals(self.machine.backend.as_ref());
        Ok(EpochStats {
            epoch_time: watch.elapsed(),
            prep_time,
            sample_time,
            extract_time,
            train_time,
            batches: batches.len(),
            train: stats,
            reorder_inversions: 0,
            ssd_read_bytes: io.read_bytes,
            ssd_read_requests: io.reads,
            extract_hist: Default::default(), // per-batch tail tracked for GNNDrive only
            align_overhead_bytes: io.align_overhead_bytes,
            truncated_edges: 0,
            io_retries: io.io_retries,
            io_failures: io.io_failures,
            direct_fallbacks: io.direct_fallbacks,
            dropped_rows: 0,
            ..Default::default()
        })
    }

    fn run_sample_only(&mut self, epoch: u64) -> Duration {
        let plan = EpochPlan::new(
            &self.ds.train_ids,
            self.cfg.batch_size,
            self.cfg.seed,
            epoch,
            self.cfg.batches_per_epoch,
        );
        let (_batches, t) = self.sample_superbatch(epoch, &plan);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn belady_cache_prefers_far_future_eviction() {
        let mut occ: HashMap<u32, VecDeque<usize>> = HashMap::new();
        occ.insert(1, VecDeque::from(vec![0, 1]));
        occ.insert(2, VecDeque::from(vec![0, 9]));
        occ.insert(3, VecDeque::from(vec![0, 2]));
        let mut fc = FeatureCache { rows: 2, resident: HashSet::new(), heap: BinaryHeap::new() };
        assert!(!fc.access(&occ, 1, 0)); // miss, insert
        assert!(!fc.access(&occ, 2, 0)); // miss, insert (full now)
        assert!(!fc.access(&occ, 3, 0)); // miss → evicts 2 (next use 9)
        assert!(fc.resident.contains(&3));
        assert!(fc.resident.contains(&1));
        assert!(!fc.resident.contains(&2));
        // 1 hits.
        assert!(fc.access(&occ, 1, 1));
    }
}
