//! Baseline training systems (PyG+, Ginex, MariusGNN) + the factory that
//! builds any system — including GNNDrive — behind the common
//! [`TrainingSystem`] trait for the comparison benches.

pub mod common;
pub mod ginex;
pub mod marius;
pub mod pygplus;

pub use common::{shared_caps, sim_trainer, SystemKind, TrainingSystem};
pub use ginex::Ginex;
pub use marius::MariusGnn;
pub use pygplus::PygPlus;

use crate::config::{Machine, TrainConfig};
use crate::graph::Dataset;
use crate::pipeline::{EpochStats, GnnDrive, Variant};
use crate::runtime::simcompute::ModelKind;
use std::sync::Arc;
use std::time::Duration;

/// Adapter: GNNDrive's pipeline engine as a `TrainingSystem`.
pub struct GnnDriveSystem {
    engine: GnnDrive,
    label: &'static str,
}

impl TrainingSystem for GnnDriveSystem {
    fn name(&self) -> &'static str {
        self.label
    }

    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats> {
        self.engine.try_run_epoch(epoch)
    }

    fn run_sample_only(&mut self, epoch: u64) -> Duration {
        self.engine.run_sample_only(epoch)
    }
}

/// Build any system under test with the shared simulated trainer (sweeps).
/// Construction failures are OOMs — a reportable result, not a crash.
///
/// Systems hold their `Machine`/`Dataset` via `Arc`, so the returned box is
/// `'static` and can be moved into spawned threads (serving loops, bench
/// drivers) instead of being pinned to the caller's stack frame.
pub fn build_system(
    kind: SystemKind,
    machine: &Arc<Machine>,
    ds: &Arc<Dataset>,
    cfg: TrainConfig,
    model: ModelKind,
) -> anyhow::Result<Box<dyn TrainingSystem + 'static>> {
    let hidden = 256; // paper §5: hidden dimension 256
    match kind {
        SystemKind::GnnDriveGpu => {
            let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Gpu, hidden);
            let engine = GnnDrive::new(machine, ds, cfg, Variant::Gpu, trainer)?;
            Ok(Box::new(GnnDriveSystem { engine, label: "GNNDrive(GPU)" }))
        }
        SystemKind::GnnDriveCpu => {
            let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Cpu, hidden);
            let engine = GnnDrive::new(machine, ds, cfg, Variant::Cpu, trainer)?;
            Ok(Box::new(GnnDriveSystem { engine, label: "GNNDrive(CPU)" }))
        }
        SystemKind::PygPlus => {
            let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Gpu, hidden);
            Ok(Box::new(PygPlus::new(machine, ds, cfg, trainer)))
        }
        SystemKind::Ginex => {
            let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Gpu, hidden);
            Ok(Box::new(Ginex::new(machine, ds, cfg, trainer)?))
        }
        SystemKind::MariusGnn => {
            let trainer = sim_trainer(machine, ds, &cfg, model, Variant::Gpu, hidden);
            Ok(Box::new(MariusGnn::new(machine, ds, cfg, trainer)?))
        }
    }
}
