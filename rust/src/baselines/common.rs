//! Shared surface for all disk-based training systems under comparison:
//! GNNDrive (GPU/CPU), PyG+, Ginex, MariusGNN. Every system runs on the
//! same substrate (one SsdSim, one page cache, one host-memory budget) with
//! the same sampler and the same (simulated or real) trainer, so measured
//! differences come from each system's memory/I-O *mechanisms* — which is
//! what the paper compares.

use crate::config::{GpuModel, Machine, TrainConfig};
use crate::graph::Dataset;
use crate::pipeline::{derive_caps, EpochStats, Variant};
use crate::runtime::simcompute::{ModelKind, SimTrainStep};
use crate::train::TrainStep;
use std::time::Duration;

/// A disk-based GNN training system under test.
pub trait TrainingSystem: Send {
    fn name(&self) -> &'static str;

    /// One full SET epoch (including any per-epoch preparation, reported in
    /// `EpochStats::prep_time`).
    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats>;

    /// Fig 2's `-only` condition: sampling alone; returns summed sample time.
    fn run_sample_only(&mut self, epoch: u64) -> Duration;
}

/// Which system to build (CLI/bench selector).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    GnnDriveGpu,
    GnnDriveCpu,
    PygPlus,
    Ginex,
    MariusGnn,
}

impl SystemKind {
    /// Case-insensitive CLI lookup ("GNNDrive", "PyG+" and "pyg+" all work).
    pub fn by_name(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "gnndrive" | "gnndrive-gpu" => Some(SystemKind::GnnDriveGpu),
            "gnndrive-cpu" => Some(SystemKind::GnnDriveCpu),
            "pyg+" | "pygplus" => Some(SystemKind::PygPlus),
            "ginex" => Some(SystemKind::Ginex),
            "marius" | "mariusgnn" => Some(SystemKind::MariusGnn),
            _ => None,
        }
    }

    /// Valid CLI names, for error messages.
    pub fn names() -> &'static str {
        "gnndrive, gnndrive-cpu, pyg+, ginex, marius"
    }

    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::GnnDriveGpu => "GNNDrive(GPU)",
            SystemKind::GnnDriveCpu => "GNNDrive(CPU)",
            SystemKind::PygPlus => "PyG+",
            SystemKind::Ginex => "Ginex",
            SystemKind::MariusGnn => "MariusGNN",
        }
    }

    pub fn all() -> [SystemKind; 5] {
        [
            SystemKind::GnnDriveGpu,
            SystemKind::GnnDriveCpu,
            SystemKind::PygPlus,
            SystemKind::Ginex,
            SystemKind::MariusGnn,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_is_case_insensitive_and_total() {
        assert_eq!(SystemKind::by_name("gnndrive"), Some(SystemKind::GnnDriveGpu));
        assert_eq!(SystemKind::by_name("GNNDrive"), Some(SystemKind::GnnDriveGpu));
        assert_eq!(SystemKind::by_name("GnnDrive-CPU"), Some(SystemKind::GnnDriveCpu));
        assert_eq!(SystemKind::by_name("PyG+"), Some(SystemKind::PygPlus));
        assert_eq!(SystemKind::by_name("MariusGNN"), Some(SystemKind::MariusGnn));
        assert_eq!(SystemKind::by_name("dgl"), None);
        for k in SystemKind::all() {
            // Every label round-trips through the case-insensitive lookup
            // except the display-only parenthetical variants.
            let _ = k.label();
        }
    }
}

/// Reference feature-buffer budget used to derive GPU-variant node caps —
/// the paper's default sizing policy (≈2.38 GB of the 24 GB device at
/// dim 128, i.e. ~10 %), scaled 1/32 with device memory. Caps derive at the
/// reference dim so node counts per batch do NOT shrink when the feature
/// dimension grows (the paper's GPU had headroom across the dim sweep);
/// only the buffer's *byte* size grows with dim.
pub const GPU_CAP_REF_BUDGET: u64 = 96 << 20;
const CAP_REF_DIM: usize = 128;

/// Derive the shared padded caps for a (machine, dataset, config) triple —
/// identical across systems so every system extracts the same byte volume.
pub fn shared_caps(
    machine: &Machine,
    ds: &Dataset,
    cfg: &TrainConfig,
    variant: Variant,
) -> Vec<usize> {
    let groups = cfg.train_queue_cap + cfg.extractors + 1;
    match variant {
        Variant::Gpu => derive_caps(
            cfg.batch_size,
            &cfg.fanouts,
            CAP_REF_DIM,
            GPU_CAP_REF_BUDGET,
            groups,
            1, // buffer mult affects slots, not caps
        ),
        // CPU training: the feature buffer competes with everything else in
        // host memory; budget a quarter of it *at the actual dim* — higher
        // dims squeeze the CPU variant, which is the paper's CPU story.
        Variant::Cpu => derive_caps(
            cfg.batch_size,
            &cfg.fanouts,
            ds.spec.dim,
            machine.host.capacity() / 4,
            groups,
            1,
        ),
    }
}

/// Build the simulated-GPU trainer every sweep system uses.
pub fn sim_trainer(
    machine: &Machine,
    ds: &Dataset,
    cfg: &TrainConfig,
    model: ModelKind,
    variant: Variant,
    hidden: usize,
) -> Box<dyn TrainStep> {
    let caps = shared_caps(machine, ds, cfg, variant);
    let gpu = match variant {
        Variant::Gpu => machine.cfg.gpu,
        Variant::Cpu => GpuModel::CpuOnly,
    };
    Box::new(SimTrainStep::new(
        gpu,
        machine.clock.clone(),
        model,
        caps,
        cfg.fanouts.clone(),
        ds.spec.dim,
        hidden, // paper default: 256
        ds.spec.classes,
    ))
}
