//! MariusGNN baseline (Waleffe et al., EuroSys '23; paper §2/§3/§5.4).
//!
//! Mechanisms reproduced:
//! * the graph is split into `P` node **partitions**; feature rows of a
//!   partition are contiguous on SSD;
//! * per-epoch **data preparation** on the critical path: compute a
//!   partition order (BETA-style, seeded permutation here) and *preload*
//!   the buffered subset into host memory with large sequential reads —
//!   the 46.1 %-of-epoch cost of Table 2;
//! * during the epoch, sampling and extraction use **only buffered
//!   partitions** (no feature I/O mid-epoch; out-of-buffer neighbors are
//!   dropped, the paper's noted accuracy risk);
//! * preparation also needs a conversion workspace ∝ feature bytes; with
//!   big feature tables this OOMs even at 128 GB — reproducing the paper's
//!   MAG240M rows. The 0.2× fraction is calibrated to the paper's observed
//!   boundary: Papers100M (53 GB features) fits in 32 GB, MAG240M (349 GB)
//!   fails even in 128 GB (DESIGN.md §3).

use super::common::TrainingSystem;
use crate::config::{Machine, TrainConfig};
use crate::graph::Dataset;
use crate::metrics::state::{self, Role};
use crate::pipeline::EpochStats;
use crate::sample::{EpochPlan, SampledSubgraph, LayerAdj};
use crate::sim::Stopwatch;
use crate::storage::{IoBackend as _, Reservation};
use crate::train::{TrainStats, TrainStep};
use crate::util::rng::Pcg;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Partition count (Marius defaults to a few dozen).
const PARTITIONS: u32 = 32;
/// Preparation workspace as a fraction of total feature bytes (calibrated
/// so the paper's OOM boundary reproduces; see module docs).
const PREP_WORKSPACE_FRAC: f64 = 0.2;
/// Fraction of host memory available for buffered partitions.
const BUFFER_FRAC: f64 = 0.6;

pub struct MariusGnn {
    machine: Arc<Machine>,
    ds: Arc<Dataset>,
    cfg: TrainConfig,
    caps: Vec<usize>,
    trainer: Mutex<Box<dyn TrainStep>>,
    part_nodes: u32,
    buffered_parts: usize,
    _buffer_res: Reservation,
}

impl MariusGnn {
    pub fn new(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: TrainConfig,
        trainer: Box<dyn TrainStep>,
    ) -> anyhow::Result<Self> {
        let caps = trainer.caps().to_vec();
        let part_nodes = ds.spec.nodes.div_ceil(PARTITIONS);
        let part_bytes = part_nodes as u64 * ds.features.row_bytes();
        let budget = (machine.host.capacity() as f64 * BUFFER_FRAC) as u64;
        let buffered_parts = (budget / part_bytes.max(1)) as usize;
        if buffered_parts < 2 {
            anyhow::bail!(
                "OOM: MariusGNN cannot buffer two partitions ({} each, budget {})",
                crate::util::units::fmt_bytes(part_bytes),
                crate::util::units::fmt_bytes(budget),
            );
        }
        let buffered_parts = buffered_parts.min(PARTITIONS as usize);
        let _buffer_res = machine
            .host
            .reserve("marius partition buffer", buffered_parts as u64 * part_bytes)?;
        Ok(MariusGnn {
            machine: machine.clone(),
            ds: ds.clone(),
            cfg,
            caps,
            trainer: Mutex::new(trainer),
            part_nodes,
            buffered_parts,
            _buffer_res,
        })
    }

    fn partition_of(&self, v: u32) -> u32 {
        v / self.part_nodes
    }

    /// Data preparation: order partitions, reserve the conversion
    /// workspace, and preload the buffered subset with sequential reads.
    fn prepare(&self, epoch: u64) -> anyhow::Result<(Vec<u32>, Duration)> {
        let clock = &self.machine.clock;
        let sw = Stopwatch::start(clock);
        let _io = state::enter(state::State::Io);

        // Conversion workspace — the OOM lever for big feature tables.
        let workspace =
            (self.ds.features.total_bytes() as f64 * PREP_WORKSPACE_FRAC) as u64;
        let _ws = self
            .machine
            .host
            .reserve("marius prep workspace", workspace)
            .map_err(|e| anyhow::anyhow!("OOM during data preparation: {e}"))?;

        // BETA-style partition ordering (seeded permutation).
        let mut order: Vec<u32> = (0..PARTITIONS).collect();
        let mut rng = Pcg::with_stream(self.cfg.seed ^ 0x3A81, epoch);
        rng.shuffle(&mut order);
        let buffered: Vec<u32> = order[..self.buffered_parts].to_vec();

        // Preload buffered partitions: large sequential feature reads
        // (bandwidth-bound) + their topology slices (buffered reads).
        let part_bytes = self.part_nodes as u64 * self.ds.features.row_bytes();
        for &p in &buffered {
            // 1 MiB sequential chunks.
            let mut left = part_bytes;
            while left > 0 {
                let chunk = left.min(1 << 20) as usize;
                self.machine.backend.charge_read(chunk);
                left -= chunk as u64;
            }
            // Topology slice of the partition through the page cache.
            let lo = (p * self.part_nodes) as usize;
            let hi = ((p + 1) * self.part_nodes).min(self.ds.spec.nodes) as usize;
            let edge_lo = self.ds.graph.indptr[lo];
            let edge_hi = self.ds.graph.indptr[hi];
            let mut left = (edge_hi - edge_lo) * 4;
            while left > 0 {
                let chunk = left.min(1 << 20) as usize;
                self.machine.backend.charge_read(chunk);
                left -= chunk as u64;
            }
        }
        Ok((buffered, sw.elapsed()))
    }

    /// In-memory sampling restricted to buffered partitions: neighbors
    /// outside the buffer are dropped (Marius's accuracy-risking shortcut).
    fn sample_in_memory(
        &self,
        buffered: &[u32],
        batch_id: u64,
        seeds: &[u32],
    ) -> SampledSubgraph {
        let in_buf: Vec<bool> = {
            let mut f = vec![false; PARTITIONS as usize];
            for &p in buffered {
                f[p as usize] = true;
            }
            f
        };
        let mut rng = Pcg::with_stream(self.cfg.seed ^ 0x0A21, batch_id);
        let mut nodes: Vec<u32> = Vec::new();
        let mut pos: HashMap<u32, i32> = HashMap::new();
        for &s in seeds {
            if in_buf[self.partition_of(s) as usize] && pos.insert(s, nodes.len() as i32).is_none()
            {
                nodes.push(s);
            }
        }
        if nodes.is_empty() {
            // Degenerate batch: keep one seed so shapes stay valid.
            nodes.push(seeds[0]);
            pos.insert(seeds[0], 0);
        }
        let mut cum = vec![nodes.len()];
        let mut adjs = Vec::new();
        let mut nbrs = Vec::new();
        for &fanout in &self.cfg.fanouts {
            let dst_count = *cum.last().unwrap();
            let mut idx = vec![-1i32; dst_count * fanout];
            for d in 0..dst_count {
                let v = nodes[d];
                nbrs.clear();
                // Buffered partitions: in-memory adjacency, no device time.
                self.ds.graph.neighbors_into_nocharge(v, &mut nbrs);
                nbrs.retain(|&s| in_buf[self.partition_of(s) as usize]);
                let deg = nbrs.len();
                if deg == 0 {
                    continue;
                }
                let take = fanout.min(deg);
                if deg > take {
                    for i in 0..take {
                        let j = rng.range(i, deg);
                        nbrs.swap(i, j);
                    }
                }
                for (f, &src) in nbrs.iter().take(take).enumerate() {
                    let local = match pos.get(&src) {
                        Some(&l) => l,
                        None => {
                            let l = nodes.len() as i32;
                            pos.insert(src, l);
                            nodes.push(src);
                            l
                        }
                    };
                    idx[d * fanout + f] = local;
                }
            }
            adjs.push(LayerAdj { fanout, idx });
            cum.push(nodes.len());
        }
        let labels = nodes[..cum[0]].iter().map(|&v| self.ds.labels[v as usize]).collect();
        SampledSubgraph { batch_id, nodes, cum, adjs, labels }
    }
}

impl TrainingSystem for MariusGnn {
    fn name(&self) -> &'static str {
        "MariusGNN"
    }

    fn run_epoch(&mut self, epoch: u64) -> anyhow::Result<EpochStats> {
        let clock = &self.machine.clock;
        let watch = Stopwatch::start(clock);
        let io_snap = crate::storage::EpochIoSnapshot::start(self.machine.backend.as_ref());
        let (first_cohort, prep_time) = self.prepare(epoch)?;

        // Cohort schedule: every partition must be buffered at some point
        // in the epoch so every train node is visited ("swapping partitions
        // is inevitable for MariusGNN at runtime", paper §4.3). The first
        // cohort was preloaded by `prepare`; subsequent cohorts pay the
        // swap-in I/O mid-epoch.
        let mut remaining: Vec<u32> =
            (0..PARTITIONS).filter(|p| !first_cohort.contains(p)).collect();
        let mut cohorts: Vec<Vec<u32>> = vec![first_cohort];
        while !remaining.is_empty() {
            let take = remaining.len().min(self.buffered_parts);
            cohorts.push(remaining.drain(..take).collect());
        }
        let batch_cap_per_cohort = self
            .cfg
            .batches_per_epoch
            .map(|c| (c / cohorts.len()).max(1));

        let mut sample_time = Duration::ZERO;
        let mut extract_time = Duration::ZERO;
        let mut train_time = Duration::ZERO;
        let mut swap_time = Duration::ZERO;
        let mut stats = TrainStats::default();
        let mut trainer = self.trainer.lock().unwrap();
        let dim = self.ds.spec.dim;
        let cap_l = *self.caps.last().unwrap();
        let mut feats = vec![0f32; cap_l * dim];
        let mut batches = 0usize;

        state::register(Role::Trainer);
        for (ci, cohort) in cohorts.iter().enumerate() {
            if ci > 0 {
                // Swap the cohort in: sequential feature reads.
                let sw = Stopwatch::start(clock);
                let _io = state::enter(state::State::Io);
                let part_bytes = self.part_nodes as u64 * self.ds.features.row_bytes();
                for _ in cohort {
                    let mut left = part_bytes;
                    while left > 0 {
                        let chunk = left.min(1 << 20) as usize;
                        self.machine.backend.charge_read(chunk);
                        left -= chunk as u64;
                    }
                }
                swap_time += sw.elapsed();
            }
            // This cohort's share of the train split.
            let in_cohort: Vec<bool> = {
                let mut f = vec![false; PARTITIONS as usize];
                for &p in cohort {
                    f[p as usize] = true;
                }
                f
            };
            let ids: Vec<u32> = self
                .ds
                .train_ids
                .iter()
                .copied()
                .filter(|&v| in_cohort[self.partition_of(v) as usize])
                .collect();
            if ids.is_empty() {
                continue;
            }
            let plan = EpochPlan::new(
                &ids,
                self.cfg.batch_size,
                self.cfg.seed ^ ci as u64,
                epoch,
                batch_cap_per_cohort,
            );
            while let Some((batch_id, seeds)) = plan.claim() {
                let sw = Stopwatch::start(clock);
                let sub = self.sample_in_memory(cohort, batch_id, seeds);
                let padded = sub.pad(&self.caps, &self.cfg.fanouts);
                sample_time += sw.elapsed();

                // Extraction is a host-memory gather (features already
                // buffered) + the H2D transfer.
                let sw = Stopwatch::start(clock);
                let mut row = vec![0u8; dim * 4];
                for (i, &v) in padded.nodes[..padded.real_nodes].iter().enumerate() {
                    self.ds.feature_gen.fill_row(v as u64, &mut row);
                    for (j, b) in row.chunks_exact(4).enumerate() {
                        feats[i * dim + j] = f32::from_le_bytes(b.try_into().unwrap());
                    }
                }
                self.machine.pcie.transfer_sync(padded.real_nodes * dim * 4);
                extract_time += sw.elapsed();

                let sw = Stopwatch::start(clock);
                let r = trainer.step(&padded, &feats);
                train_time += sw.elapsed();
                stats.push(&r);
                batches += 1;
            }
        }
        extract_time += swap_time; // mid-epoch swaps are extraction-side I/O
        state::deregister();

        let io = io_snap.totals(self.machine.backend.as_ref());
        Ok(EpochStats {
            epoch_time: watch.elapsed(),
            prep_time,
            sample_time,
            extract_time,
            train_time,
            batches,
            train: stats,
            reorder_inversions: 0,
            ssd_read_bytes: io.read_bytes,
            ssd_read_requests: io.reads,
            extract_hist: Default::default(), // per-batch tail tracked for GNNDrive only
            align_overhead_bytes: io.align_overhead_bytes,
            truncated_edges: 0,
            io_retries: io.io_retries,
            io_failures: io.io_failures,
            direct_fallbacks: io.direct_fallbacks,
            dropped_rows: 0,
            ..Default::default()
        })
    }

    fn run_sample_only(&mut self, epoch: u64) -> Duration {
        let clock = &self.machine.clock;
        let Ok((buffered, _)) = self.prepare(epoch) else {
            return Duration::ZERO;
        };
        let plan = EpochPlan::new(
            &self.ds.train_ids,
            self.cfg.batch_size,
            self.cfg.seed,
            epoch,
            self.cfg.batches_per_epoch,
        );
        let sw = Stopwatch::start(clock);
        while let Some((batch_id, seeds)) = plan.claim() {
            let sub = self.sample_in_memory(&buffered, batch_id, seeds);
            std::hint::black_box(&sub);
        }
        sw.elapsed()
    }
}
