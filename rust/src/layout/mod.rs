//! Offline pre-sampling + packed per-batch on-disk feature layout
//! (DiskGNN-style, ROADMAP direction 2).
//!
//! The online extraction path pays one random (coalesced) read pattern per
//! batch because batch membership is only known at train time. But the batch
//! sequence is *deterministic* in the [`crate::sample::ScheduleSpec`]: seed,
//! batch size, fanouts and per-epoch cap pin every `(epoch, batch_id)` →
//! node-set mapping bit-for-bit. `pack_dataset` exploits that by running the
//! sampler offline over the epochs' seed schedules and rewriting the feature
//! rows each batch will touch into a layout the train-time extractor can
//! read *sequentially*:
//!
//! - **Hot tier** (`hot.bin`): rows appearing in at least `hot_thresh`
//!   batches are stored exactly once, in ascending node order, and pinned
//!   into the [`crate::membuf::FeatureBuffer`] at attach time ([`pin_hot`]) —
//!   the Ginex-style cache, but computed from the *exact* future access
//!   trace instead of a degree heuristic.
//! - **Cold packs** (`packs.bin`, or `packs.bin.{0..N-1}` striped): for every
//!   `(epoch, batch)`, the batch's non-hot rows are laid out back to back as
//!   one run whose start is aligned to the stripe chunk (striped) or the
//!   device sector (unstriped). A run is read with ~one large sequential
//!   request per device instead of hundreds of scattered row reads, and its
//!   alignment padding lives *between* runs on disk, never inside a request —
//!   so packed extraction's `align_overhead_bytes` drops below the online
//!   coalesced plan's.
//! - **Index** (`packs.idx` + `pack_*` keys in `meta.toml`): per-run byte
//!   offsets and row tables, plus the schedule and stripe geometry the pack
//!   was computed under. [`PackedLayout::load_dir`] refuses a machine with a
//!   different pack geometry and [`PackedLayout::verify_schedule`] refuses a
//!   trainer whose schedule would diverge from the pre-sampled one —
//!   mirroring the dataset stripe-geometry handshake.
//!
//! Rows are duplicated across pack runs (classic space-for-I/O trade): disk
//! grows by roughly the epoch's cold traffic, while charged SSD requests per
//! packed batch collapse to ~`devices` + a few hot stragglers. Any batch the
//! pack does not cover — extra epochs, a longer cap, a node the row tables
//! cannot place — silently falls back to the online plan, byte-identical to
//! an unpacked run.

use crate::config::Machine;
use crate::graph::Dataset;
use crate::membuf::FeatureBuffer;
use crate::sample::ScheduleSpec;
use crate::storage::{
    BackingRef, DataKind, FileBacking, FileId, IoBackend, SimFile, StripeSpec, StripedBacking,
};
use std::collections::HashMap;
use std::io::Write;
use std::path::Path;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

/// `packs.idx` magic (version 1).
const IDX_MAGIC: &[u8; 8] = b"GNNPACK1";

/// Pack files get their own file-id range so they never collide with the
/// dataset loader's ids in the page cache / per-file accounting.
fn next_file_id() -> u32 {
    static NEXT: AtomicU32 = AtomicU32::new(9000);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Summary of one `pack_dataset` run (reported by the `pack` subcommand and
/// asserted on by the layout bench).
#[derive(Debug, Clone)]
pub struct PackStats {
    pub epochs: u64,
    pub batches_per_epoch: u64,
    /// Rows promoted to the hot tier (stored once in `hot.bin`).
    pub hot_rows: u64,
    /// Cold rows written across all pack runs (with duplication).
    pub cold_rows: u64,
    /// Total bytes of `packs.bin` (all members), padding included.
    pub pack_bytes: u64,
    /// Alignment padding bytes between runs.
    pub pad_bytes: u64,
}

/// Sequential writer for the pack file(s): streams logical bytes in order
/// and splits them across striped members at chunk boundaries, so every
/// member file is a pure append (same invariant as
/// [`crate::graph::FeatureTable::write_file_striped`]).
struct PackWriter {
    writers: Vec<std::io::BufWriter<std::fs::File>>,
    spec: StripeSpec,
    off: u64,
}

impl PackWriter {
    fn create(dir: &Path, spec: StripeSpec) -> std::io::Result<PackWriter> {
        let paths: Vec<std::path::PathBuf> = if spec.is_striped() {
            (0..spec.devices).map(|d| dir.join(format!("packs.bin.{d}"))).collect()
        } else {
            vec![dir.join("packs.bin")]
        };
        let mut writers = Vec::with_capacity(paths.len());
        for p in &paths {
            writers.push(std::io::BufWriter::with_capacity(1 << 20, std::fs::File::create(p)?));
        }
        Ok(PackWriter { writers, spec, off: 0 })
    }

    fn write(&mut self, buf: &[u8]) -> std::io::Result<()> {
        if self.writers.len() == 1 {
            self.writers[0].write_all(buf)?;
        } else {
            let mut taken = 0usize;
            for (dev, _local, run) in self.spec.split(self.off, buf.len()) {
                self.writers[dev].write_all(&buf[taken..taken + run])?;
                taken += run;
            }
        }
        self.off += buf.len() as u64;
        Ok(())
    }

    /// Zero-pad to the next multiple of `align`; returns the pad size.
    fn pad_to(&mut self, align: u64) -> std::io::Result<u64> {
        let pad = (align - self.off % align) % align;
        if pad > 0 {
            self.write(&vec![0u8; pad as usize])?;
        }
        Ok(pad)
    }

    fn finish(mut self) -> std::io::Result<u64> {
        for w in &mut self.writers {
            w.flush()?;
        }
        Ok(self.off)
    }
}

/// Pre-sample `epochs` epochs of `schedule` over `ds` and write the packed
/// layout (`hot.bin`, `packs.bin[.d]`, `packs.idx`, `pack_*` meta keys) into
/// `dir` — the directory the dataset was `gen-data`'d into. Re-packing
/// replaces any previous pack in place.
pub fn pack_dataset(
    machine: &Machine,
    ds: &Dataset,
    dir: &Path,
    schedule: &ScheduleSpec,
    epochs: u64,
    hot_thresh: u32,
) -> anyhow::Result<PackStats> {
    anyhow::ensure!(epochs > 0, "pack: need at least one epoch");
    anyhow::ensure!(hot_thresh > 0, "pack: --pack-hot-thresh must be positive");

    // 1. Offline pre-sampling: replay the exact batch sequence the trainer
    //    will run (same plan, same per-batch sampler streams) and record
    //    each batch's sampled node set.
    let mut per_epoch: Vec<Vec<Vec<u32>>> = Vec::with_capacity(epochs as usize);
    for epoch in 0..epochs {
        let plan = schedule.plan(&ds.train_ids, epoch);
        let sampler = schedule.sampler(epoch);
        let mut batches: Vec<Vec<u32>> = Vec::with_capacity(plan.len());
        while let Some((batch_id, seeds)) = plan.claim() {
            let sg = sampler.sample_batch(ds, machine.backend.as_ref(), batch_id, seeds);
            debug_assert_eq!(batch_id as usize, batches.len(), "serial claim is in order");
            batches.push(sg.nodes);
        }
        per_epoch.push(batches);
    }
    let batches_per_epoch = per_epoch[0].len() as u64;
    anyhow::ensure!(batches_per_epoch > 0, "pack: schedule yields zero batches");

    // 2. Hot/cold split: batch-frequency per node across the whole plan.
    let mut freq: HashMap<u32, u32> = HashMap::new();
    for batches in &per_epoch {
        for nodes in batches {
            for &n in nodes {
                *freq.entry(n).or_insert(0) += 1;
            }
        }
    }
    let mut hot: Vec<u32> =
        freq.iter().filter(|&(_, &c)| c >= hot_thresh).map(|(&n, _)| n).collect();
    hot.sort_unstable();
    let hot_set: std::collections::HashSet<u32> = hot.iter().copied().collect();

    let row_bytes = ds.features.row_bytes();
    let mut row = vec![0u8; row_bytes as usize];

    // 3. Hot tier: each hot row once, ascending node order (rank == index).
    {
        let f = std::fs::File::create(dir.join("hot.bin"))?;
        let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
        for &n in &hot {
            ds.features.file.backing.read_at(ds.features.row_offset(n as u64), &mut row);
            w.write_all(&row)?;
        }
        w.flush()?;
    }

    // 4. Cold packs: one aligned sequential run per (epoch, batch). Runs
    //    start on a stripe-chunk (striped) or sector (unstriped) boundary so
    //    the direct-I/O read of a run never widens past the run itself.
    let spec = machine.cfg.stripe_spec();
    let align = if spec.is_striped() { spec.stripe_bytes } else { machine.backend.sector() as u64 };
    let mut pw = PackWriter::create(dir, spec)?;
    let mut runs: Vec<(u64, Vec<u32>)> = Vec::with_capacity((epochs * batches_per_epoch) as usize);
    let mut cold_rows = 0u64;
    let mut pad_bytes = 0u64;
    for batches in &per_epoch {
        anyhow::ensure!(
            batches.len() as u64 == batches_per_epoch,
            "pack: epoch batch counts diverge ({} vs {batches_per_epoch})",
            batches.len()
        );
        for nodes in batches {
            pad_bytes += pw.pad_to(align)?;
            let offset = pw.off;
            let cold: Vec<u32> = nodes.iter().copied().filter(|n| !hot_set.contains(n)).collect();
            for &n in &cold {
                ds.features.file.backing.read_at(ds.features.row_offset(n as u64), &mut row);
                pw.write(&row)?;
            }
            cold_rows += cold.len() as u64;
            runs.push((offset, cold));
        }
    }
    let pack_bytes = pw.finish()?;

    // 5. Index: binary row tables + human-auditable schedule/geometry keys
    //    in meta.toml (the handshake side).
    write_index(&dir.join("packs.idx"), epochs, batches_per_epoch, &hot, &runs)?;
    write_meta_keys(
        &dir.join("meta.toml"),
        schedule,
        epochs,
        batches_per_epoch,
        hot_thresh,
        spec,
        hot.len() as u64,
    )?;

    Ok(PackStats {
        epochs,
        batches_per_epoch,
        hot_rows: hot.len() as u64,
        cold_rows,
        pack_bytes,
        pad_bytes,
    })
}

fn write_index(
    path: &Path,
    epochs: u64,
    batches_per_epoch: u64,
    hot: &[u32],
    runs: &[(u64, Vec<u32>)],
) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = std::io::BufWriter::with_capacity(1 << 20, f);
    w.write_all(IDX_MAGIC)?;
    w.write_all(&epochs.to_le_bytes())?;
    w.write_all(&batches_per_epoch.to_le_bytes())?;
    w.write_all(&(hot.len() as u64).to_le_bytes())?;
    for &n in hot {
        w.write_all(&n.to_le_bytes())?;
    }
    for (offset, nodes) in runs {
        w.write_all(&offset.to_le_bytes())?;
        w.write_all(&(nodes.len() as u64).to_le_bytes())?;
        for &n in nodes {
            w.write_all(&n.to_le_bytes())?;
        }
    }
    w.flush()
}

/// Append (or replace) the `pack_*` keys in `meta.toml`. The keys are the
/// load-time handshake: schedule identity + the stripe geometry the pack
/// offsets were computed under.
fn write_meta_keys(
    meta_path: &Path,
    schedule: &ScheduleSpec,
    epochs: u64,
    batches_per_epoch: u64,
    hot_thresh: u32,
    spec: StripeSpec,
    hot_rows: u64,
) -> anyhow::Result<()> {
    let old = std::fs::read_to_string(meta_path)?;
    let mut meta: String =
        old.lines().filter(|l| !l.trim_start().starts_with("pack_")).collect::<Vec<_>>().join("\n");
    if !meta.is_empty() && !meta.ends_with('\n') {
        meta.push('\n');
    }
    meta.push_str(&format!(
        "pack_seed = {}\npack_batch_size = {}\npack_fanouts = \"{}\"\npack_epochs = {}\n\
         pack_batches = {}\npack_hot_thresh = {}\npack_hot_rows = {}\n\
         pack_devices = {}\npack_stripe_bytes = {}\n",
        schedule.seed,
        schedule.batch_size,
        schedule.fanouts_str(),
        epochs,
        batches_per_epoch,
        hot_thresh,
        hot_rows,
        spec.devices,
        spec.stripe_bytes,
    ));
    std::fs::write(meta_path, meta)?;
    Ok(())
}

/// One pre-sampled batch's extraction plan, resolved against the buffer's
/// `to_load` list: byte offsets into the pack file / hot file per missing
/// row. Produced by [`PackedLayout::plan_batch`], consumed by
/// [`crate::extract::Extractor::try_extract_at`].
pub struct PackedBatchPlan {
    /// `(pack-file byte offset, node, slot)` — rows of this batch's
    /// sequential run, contiguous up to already-resident holes.
    pub pack_rows: Vec<(u64, u32, u32)>,
    /// `(hot-file byte offset, node, slot)` — hot-tier rows not (yet)
    /// buffer-resident, e.g. before/without pinning.
    pub hot_rows: Vec<(u64, u32, u32)>,
}

/// One `(epoch, batch)` pack run: its byte offset and node → row-rank table.
struct PackEntry {
    offset: u64,
    rank: HashMap<u32, u32>,
}

/// A loaded packed layout: the index in memory plus open handles to the pack
/// and hot files. Shared read-only across extractors (`Arc`).
pub struct PackedLayout {
    /// Schedule the pack was pre-sampled under (handshake identity).
    pub seed: u64,
    pub batch_size: usize,
    pub fanouts: Vec<usize>,
    pub epochs: u64,
    pub batches_per_epoch: u64,
    pub hot_thresh: u32,
    /// Hot-tier node ids, ascending; index in this list == row rank in
    /// `hot.bin`.
    pub hot: Vec<u32>,
    hot_rank: HashMap<u32, u32>,
    entries: Vec<PackEntry>,
    pub packs: SimFile,
    pub hot_file: SimFile,
    pub row_bytes: u64,
}

impl PackedLayout {
    /// Open the packed layout written by [`pack_dataset`] into `dir`.
    /// Fails with a "not packed" error when the `pack_*` keys are absent,
    /// and with an expected-vs-actual geometry error when the machine's
    /// `--devices`/`--stripe-bytes` differ from the pack's — the same
    /// handshake contract as the dataset stripe geometry check.
    pub fn load_dir(dir: &Path, machine: &Machine) -> anyhow::Result<PackedLayout> {
        let meta_path = dir.join("meta.toml");
        let meta = crate::util::toml::Doc::parse(&std::fs::read_to_string(&meta_path)?)
            .map_err(anyhow::Error::msg)?;
        let seed = meta.get_i64("pack_seed").ok_or_else(|| {
            anyhow::anyhow!(
                "dataset at {} is not packed (no pack_* keys in meta.toml); \
                 run `gnndrive pack --data …` first",
                dir.display()
            )
        })? as u64;
        let need = |k: &str| {
            meta.get_i64(k).ok_or_else(|| anyhow::anyhow!("meta.toml: missing pack key {k}"))
        };
        let batch_size = need("pack_batch_size")? as usize;
        let epochs = need("pack_epochs")? as u64;
        let batches_per_epoch = need("pack_batches")? as u64;
        let hot_thresh = need("pack_hot_thresh")? as u32;
        let pack_devices = need("pack_devices")?.max(1) as usize;
        let pack_stripe_bytes = need("pack_stripe_bytes")?.max(1) as u64;
        let fanouts_s = meta
            .get_str("pack_fanouts")
            .ok_or_else(|| anyhow::anyhow!("meta.toml: missing pack key pack_fanouts"))?;
        let fanouts: Vec<usize> = fanouts_s
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("meta.toml: bad pack_fanouts {fanouts_s:?}: {e}"))?;
        let dim = meta.get_i64("dim").ok_or_else(|| anyhow::anyhow!("meta: dim"))? as usize;
        let row_bytes = (dim * 4) as u64;

        // Pack stripe-geometry handshake (mirrors the dataset one): run
        // offsets were aligned to this geometry; a different machine layout
        // would mistranslate logical ↔ device offsets.
        let pack_spec = StripeSpec::new(pack_devices, pack_stripe_bytes);
        let m_spec = machine.cfg.stripe_spec();
        if pack_spec != m_spec {
            anyhow::bail!(
                "packed layout stripe geometry mismatch: meta.toml expects {} device(s) with \
                 stripe {} B, but the CLI (--devices/--stripe-bytes) configured {} device(s) \
                 with stripe {} B; pass matching flags or re-run `gnndrive pack`",
                pack_spec.devices,
                pack_spec.stripe_bytes,
                m_spec.devices,
                m_spec.stripe_bytes,
            );
        }

        let packs_backing: BackingRef = if pack_spec.is_striped() {
            let mut members: Vec<BackingRef> = Vec::with_capacity(pack_devices);
            for d in 0..pack_devices {
                members.push(Arc::new(FileBacking::open(&dir.join(format!("packs.bin.{d}")))?));
            }
            Arc::new(StripedBacking::new(members, pack_stripe_bytes))
        } else {
            Arc::new(FileBacking::open(&dir.join("packs.bin"))?)
        };
        let packs = SimFile::new(FileId::new(next_file_id(), DataKind::Features), packs_backing);
        let hot_backing: BackingRef = Arc::new(FileBacking::open(&dir.join("hot.bin"))?);
        let hot_file = SimFile::new(FileId::new(next_file_id(), DataKind::Features), hot_backing);

        let (hot, entries) = read_index(&dir.join("packs.idx"), epochs, batches_per_epoch)?;
        let hot_rank: HashMap<u32, u32> =
            hot.iter().enumerate().map(|(i, &n)| (n, i as u32)).collect();

        Ok(PackedLayout {
            seed,
            batch_size,
            fanouts,
            epochs,
            batches_per_epoch,
            hot_thresh,
            hot,
            hot_rank,
            entries,
            packs,
            hot_file,
            row_bytes,
        })
    }

    /// Refuse a trainer schedule that would diverge from the pre-sampled
    /// one. Strict on sampler seed / batch size / fanouts (any difference
    /// changes batch node sets); the per-epoch cap may differ — a capped
    /// plan is a prefix of the uncapped one, so a shorter train run replays
    /// exactly and a longer one falls back online past the packed range.
    pub fn verify_schedule(&self, spec: &ScheduleSpec) -> anyhow::Result<()> {
        if spec.seed != self.seed
            || spec.batch_size != self.batch_size
            || spec.fanouts != self.fanouts
        {
            anyhow::bail!(
                "packed layout schedule mismatch: meta.toml expects pack sampler seed {} \
                 (batch size {}, fanouts \"{}\"), but the CLI configured seed {} (batch size {}, \
                 fanouts \"{}\"); pass matching --seed/--batch-size/--fanouts or re-run \
                 `gnndrive pack`",
                self.seed,
                self.batch_size,
                self.fanouts.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(","),
                spec.seed,
                spec.batch_size,
                spec.fanouts.iter().map(|f| f.to_string()).collect::<Vec<_>>().join(","),
            );
        }
        Ok(())
    }

    /// Whether `node` is in the hot tier.
    pub fn is_hot(&self, node: u32) -> bool {
        self.hot_rank.contains_key(&node)
    }

    /// Resolve a batch's missing rows against the pack: `Some` with per-row
    /// byte offsets when `(epoch, batch_id)` is covered and *every* missing
    /// row can be placed (pack run or hot tier); `None` → caller falls back
    /// to the online plan for the whole batch.
    pub fn plan_batch(
        &self,
        epoch: u64,
        batch_id: u64,
        to_load: &[(u32, u32)],
    ) -> Option<PackedBatchPlan> {
        if epoch >= self.epochs || batch_id >= self.batches_per_epoch {
            return None;
        }
        let entry = self.entries.get((epoch * self.batches_per_epoch + batch_id) as usize)?;
        let mut pack_rows = Vec::with_capacity(to_load.len());
        let mut hot_rows = Vec::new();
        for &(node, slot) in to_load {
            if let Some(&r) = entry.rank.get(&node) {
                pack_rows.push((entry.offset + r as u64 * self.row_bytes, node, slot));
            } else if let Some(&r) = self.hot_rank.get(&node) {
                hot_rows.push((r as u64 * self.row_bytes, node, slot));
            } else {
                // A row the pre-sampler never saw for this batch: the
                // schedules diverged (shouldn't happen post-handshake) or
                // the caller passed a foreign batch. Punt wholesale.
                return None;
            }
        }
        Some(PackedBatchPlan { pack_rows, hot_rows })
    }
}

fn take<'a>(bytes: &'a [u8], pos: &mut usize, n: usize) -> anyhow::Result<&'a [u8]> {
    let s = bytes
        .get(*pos..*pos + n)
        .ok_or_else(|| anyhow::anyhow!("packs.idx truncated at byte {pos}"))?;
    *pos += n;
    Ok(s)
}

fn rd_u64(bytes: &[u8], pos: &mut usize) -> anyhow::Result<u64> {
    Ok(u64::from_le_bytes(take(bytes, pos, 8)?.try_into().unwrap()))
}

fn rd_u32(bytes: &[u8], pos: &mut usize) -> anyhow::Result<u32> {
    Ok(u32::from_le_bytes(take(bytes, pos, 4)?.try_into().unwrap()))
}

fn read_index(
    path: &Path,
    epochs: u64,
    batches_per_epoch: u64,
) -> anyhow::Result<(Vec<u32>, Vec<PackEntry>)> {
    let bytes = std::fs::read(path)?;
    let mut pos = 0usize;
    let magic = take(&bytes, &mut pos, 8)?;
    anyhow::ensure!(magic == IDX_MAGIC, "packs.idx: bad magic {magic:?}");
    let idx_epochs = rd_u64(&bytes, &mut pos)?;
    let idx_batches = rd_u64(&bytes, &mut pos)?;
    anyhow::ensure!(
        idx_epochs == epochs && idx_batches == batches_per_epoch,
        "packs.idx disagrees with meta.toml: {idx_epochs}×{idx_batches} vs \
         {epochs}×{batches_per_epoch} (re-run `gnndrive pack`)"
    );
    let hot_count = rd_u64(&bytes, &mut pos)? as usize;
    let mut hot = Vec::with_capacity(hot_count);
    for _ in 0..hot_count {
        hot.push(rd_u32(&bytes, &mut pos)?);
    }
    let n_entries = (epochs * batches_per_epoch) as usize;
    let mut entries = Vec::with_capacity(n_entries);
    for _ in 0..n_entries {
        let offset = rd_u64(&bytes, &mut pos)?;
        let n_rows = rd_u64(&bytes, &mut pos)? as usize;
        let mut rank = HashMap::with_capacity(n_rows);
        for r in 0..n_rows {
            rank.insert(rd_u32(&bytes, &mut pos)?, r as u32);
        }
        entries.push(PackEntry { offset, rank });
    }
    anyhow::ensure!(pos == bytes.len(), "packs.idx: {} trailing byte(s)", bytes.len() - pos);
    Ok((hot, entries))
}

/// Pin up to `budget` hot-tier rows into the feature buffer and never
/// release them: their references hold the rows resident for the whole run,
/// so every later batch aliases them for free (`hot_hits`). Loads are
/// charged as large sequential reads — `hot.bin` is read front to back.
/// Returns the number of rows pinned; callers size `budget` to the slots the
/// pipeline can spare ([`crate::pipeline::GnnDrive::attach_layout`]).
pub fn pin_hot(
    fb: &FeatureBuffer,
    layout: &PackedLayout,
    io: &dyn IoBackend,
    budget: usize,
) -> usize {
    pin_hot_from(fb, layout, io, budget, 0)
}

/// [`pin_hot`] starting at hot-rank `start` instead of rank 0: the overflow
/// path of tiered placement, which pins the head of `hot.bin` into the GPU
/// tier ([`pin_hot_gpu`]) and hands the remainder to the host buffer.
pub fn pin_hot_from(
    fb: &FeatureBuffer,
    layout: &PackedLayout,
    io: &dyn IoBackend,
    budget: usize,
    start: usize,
) -> usize {
    let start = start.min(layout.hot.len());
    let n = budget.min(layout.hot.len() - start);
    if n == 0 {
        return 0;
    }
    let row_bytes = layout.row_bytes as usize;
    let mut buf = vec![0u8; row_bytes];
    let mut pinned = 0usize;
    // Chunked so each begin_batch stays far below the buffer's claimable
    // headroom (the caller's budget guarantees total fit).
    for chunk in layout.hot[start..start + n].chunks(256) {
        let plan = fb.begin_batch(chunk);
        for &(node, slot) in &plan.to_load {
            let r = layout.hot_rank[&node];
            layout.hot_file.backing.read_at(r as u64 * layout.row_bytes, &mut buf);
            fb.publish_le_bytes(node, slot, &buf);
        }
        if !plan.to_load.is_empty() {
            io.charge_read(plan.to_load.len() * row_bytes);
        }
        fb.wait_plan(&plan);
        // Intentionally no release: the plan's references are the pin.
        pinned += chunk.len();
    }
    pinned
}

/// Pin the head of `hot.bin` into the GPU hot tier (`--packed` +
/// `--tier gpu`): rows go in hot-rank order until the tier's free list is
/// exhausted, so the hottest rows sit one PCIe hop from compute and the
/// remainder overflows to the host pin ([`pin_hot_from`]). SSD loads charge
/// through `io` in the same 256-row bursts as the host pin; the host→device
/// upload charges through the store's PCIe model. Returns rows pinned (0 in
/// host mode).
pub fn pin_hot_gpu(
    store: &crate::tier::TieredFeatureStore,
    layout: &PackedLayout,
    io: &dyn IoBackend,
) -> usize {
    if !store.is_gpu() {
        return 0;
    }
    let row_bytes = layout.row_bytes as usize;
    let mut buf = vec![0u8; row_bytes];
    let mut pinned = 0usize;
    let mut burst = 0usize;
    for &node in &layout.hot {
        let r = layout.hot_rank[&node];
        layout.hot_file.backing.read_at(r as u64 * layout.row_bytes, &mut buf);
        if !store.pin_gpu_row(node, &buf) {
            break; // tier full — the rest overflows to the host pin
        }
        pinned += 1;
        burst += 1;
        if burst == 256 {
            io.charge_read(burst * row_bytes);
            store.charge_tier_upload(burst * row_bytes);
            burst = 0;
        }
    }
    if burst > 0 {
        io.charge_read(burst * row_bytes);
        store.charge_tier_upload(burst * row_bytes);
    }
    pinned
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::MachineConfig;
    use crate::graph::DatasetSpec;
    use crate::sim::Clock;

    fn tiny_spec() -> DatasetSpec {
        DatasetSpec::by_name("unit-test").unwrap()
    }

    fn temp_dir(stem: &str) -> std::path::PathBuf {
        static SEQ: AtomicU32 = AtomicU32::new(0);
        let d = std::env::temp_dir().join(format!(
            "gnndrive_layout_{stem}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn schedule() -> ScheduleSpec {
        ScheduleSpec { seed: 17, batch_size: 64, fanouts: vec![4, 4], batches_per_epoch: Some(4) }
    }

    #[test]
    fn pack_then_load_roundtrips_and_places_every_row() {
        let dir = temp_dir("roundtrip");
        let spec = tiny_spec();
        Dataset::write_dir(&spec, &dir).unwrap();
        let machine = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::load_dir(&dir, &machine).unwrap();
        let sched = schedule();
        let stats = pack_dataset(&machine, &ds, &dir, &sched, 2, 2).unwrap();
        assert_eq!(stats.epochs, 2);
        assert_eq!(stats.batches_per_epoch, 4);

        let layout = PackedLayout::load_dir(&dir, &machine).unwrap();
        layout.verify_schedule(&sched).unwrap();
        assert_eq!(layout.hot.len() as u64, stats.hot_rows);

        // Replay the schedule: every sampled node of every covered batch
        // must place (pack run or hot tier), and pack rows must read back
        // the exact feature bytes.
        let mut row = vec![0u8; ds.features.row_bytes() as usize];
        let mut expect = vec![0u8; ds.features.row_bytes() as usize];
        for epoch in 0..2u64 {
            let plan = sched.plan(&ds.train_ids, epoch);
            let sampler = sched.sampler(epoch);
            while let Some((bid, seeds)) = plan.claim() {
                let nodes = sampler.sample_batch(&ds, machine.backend.as_ref(), bid, seeds).nodes;
                let to_load: Vec<(u32, u32)> =
                    nodes.iter().map(|&n| (n, 0u32)).collect();
                let pp = layout.plan_batch(epoch, bid, &to_load).expect("batch covered");
                assert_eq!(pp.pack_rows.len() + pp.hot_rows.len(), nodes.len());
                for &(off, node, _) in pp.pack_rows.iter().take(8) {
                    layout.packs.backing.read_at(off, &mut row);
                    ds.features.file.backing.read_at(ds.features.row_offset(node as u64), &mut expect);
                    assert_eq!(row, expect, "pack row for node {node}");
                }
                for &(off, node, _) in pp.hot_rows.iter().take(8) {
                    layout.hot_file.backing.read_at(off, &mut row);
                    ds.features.file.backing.read_at(ds.features.row_offset(node as u64), &mut expect);
                    assert_eq!(row, expect, "hot row for node {node}");
                }
            }
        }
        // Outside the packed range: graceful fallback.
        assert!(layout.plan_batch(2, 0, &[]).is_none());
        assert!(layout.plan_batch(0, 99, &[]).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn schedule_mismatch_is_refused_with_expected_vs_actual() {
        let dir = temp_dir("handshake");
        let spec = tiny_spec();
        Dataset::write_dir(&spec, &dir).unwrap();
        let machine = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::load_dir(&dir, &machine).unwrap();
        let sched = schedule();
        pack_dataset(&machine, &ds, &dir, &sched, 1, 2).unwrap();
        let layout = PackedLayout::load_dir(&dir, &machine).unwrap();

        let mut other = sched.clone();
        other.seed ^= 1;
        let err = layout.verify_schedule(&other).unwrap_err().to_string();
        assert!(err.contains("pack sampler seed"), "{err}");
        assert!(err.contains(&format!("seed {}", sched.seed)), "{err}");
        assert!(err.contains(&format!("seed {}", other.seed)), "{err}");
        // Cap-only differences are allowed (prefix replay).
        let mut capped = sched.clone();
        capped.batches_per_epoch = Some(2);
        layout.verify_schedule(&capped).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_starts_are_aligned() {
        let dir = temp_dir("align");
        let spec = tiny_spec();
        Dataset::write_dir(&spec, &dir).unwrap();
        let machine = Machine::new(MachineConfig::paper(), Clock::new(0.05));
        let ds = Dataset::load_dir(&dir, &machine).unwrap();
        pack_dataset(&machine, &ds, &dir, &schedule(), 1, 2).unwrap();
        let layout = PackedLayout::load_dir(&dir, &machine).unwrap();
        let sector = machine.backend.sector() as u64;
        for e in &layout.entries {
            assert_eq!(e.offset % sector, 0, "run offset {} not sector-aligned", e.offset);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
