//! The serving engine: a long-lived multi-tenant online-inference frontend
//! over the training stack's sampling → coalesced-extraction →
//! feature-buffer path.
//!
//! One [`ServeEngine`] owns the feature buffer(s) and drives one serving
//! *run* ([`ServeEngine::run`]) at a time: load generators (open-loop
//! Poisson arrivals at `--rps`, or `--clients` closed-loop callers) feed the
//! bounded admission queue; the micro-batcher groups admitted requests into
//! inference batches (`--serve-batch` / `--serve-wait`); serving workers
//! sample each batch's seed nodes, extract their features through the
//! *training* extractor (async direct I/O, segment coalescing across the
//! whole batch — including across tenants), gather and run a read-only
//! forward pass ([`crate::train::TrainStep::forward`]), and release the
//! aliases. Every stage's latency lands in a mergeable log-bucketed
//! histogram; the report carries p50/p95/p99 per stage plus charged-I/O and
//! buffer-reuse accounting.
//!
//! **Shared tenancy** is the default and the point: all workers (and the
//! optional concurrent trainer, `--serve-while-train`) share one
//! [`FeatureBuffer`], so one tenant's hot-node extraction becomes every
//! other tenant's buffer hit. The `--per-tenant-buffer` ablation gives each
//! tenant a private buffer of the *same* slot count (memory-generous to the
//! ablation) and forces per-tenant micro-batches; it still loses on p99
//! extract latency and charged SSD requests because hot rows are re-read
//! once per tenant and batches stop coalescing across tenants — the
//! acceptance gate `benches/serve_latency.rs` measures.
//!
//! Layer ownership: the *admission queue* owns the shed-vs-admit decision
//! (bounded, never parks an open-loop request), the *batcher* owns
//! execution grouping (size/linger bounds, buffer-group keying), the
//! *engine* owns tenancy (how many buffers, who shares) and the stage
//! pipeline. Works unchanged on `--backend sim` and `--backend os`.

use super::batcher::{run_batcher, BatchSpec, InferBatch};
use super::request::{
    run_closed_loop_client, run_open_loop, Admission, AdmissionCounts, SeedSkew,
};
use crate::config::Machine;
use crate::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor, HedgeConfig};
use crate::graph::Dataset;
use crate::membuf::{FeatureBuffer, StagingBuffer};
use crate::metrics::state::{self, Role};
use crate::pipeline::derive_caps;
use crate::runtime::simcompute::{ModelKind, SimTrainStep};
use crate::sample::{EpochPlan, Sampler};
use crate::sim::queue::BoundedQueue;
use crate::sim::Stopwatch;
use crate::storage::EpochIoSnapshot;
use crate::tier::{TierKind, TierPolicy, TierSnapshot, TieredFeatureStore};
use crate::train::TrainStep;
use crate::util::stats::LatencyHist;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serving-run parameters (CLI `serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Request streams (tenants). Seed popularity is shared across streams.
    pub tenants: usize,
    /// Serving worker threads (each owns a sampler + extractor per buffer).
    pub workers: usize,
    /// Total requests per run.
    pub requests: u64,
    /// Open-loop Poisson arrival rate in requests per *sim* second;
    /// `0` selects the closed loop.
    pub rps: f64,
    /// Closed-loop concurrency (ignored when `rps > 0`).
    pub clients: usize,
    /// Admission-queue bound: offers beyond it are shed, never queued.
    pub admit_cap: usize,
    /// Micro-batch size / linger bounds (`--serve-batch` / `--serve-wait`;
    /// the linger is in sim units — `run` converts it to real time for the
    /// batcher's wall-clock deadline).
    pub batch: BatchSpec,
    /// Neighbor fanouts of the inference sample (innermost first).
    pub fanouts: Vec<usize>,
    /// io_uring/pool depth per extractor.
    pub io_depth: usize,
    /// Segment-coalescing knobs (shared with training).
    pub coalesce: CoalesceConfig,
    /// Feature-buffer size multiplier over the minimum `(workers + trainer
    /// + 1) × cap_L` (Fig 12's knob, serving edition: extra slots are pure
    /// cross-request residency). Clamped to the per-tenant budget share.
    pub buffer_mult: usize,
    /// Ablation: one private feature buffer per tenant (same slot count
    /// each) instead of one shared buffer.
    pub per_tenant_buffer: bool,
    /// Run a concurrent training loop over the shared buffer.
    pub serve_while_train: bool,
    /// Seed-popularity hot-prefix size; `0` = skew over the whole graph.
    /// Real serving traffic concentrates on a head of popular entities —
    /// this is its size knob (`--hot-nodes`).
    pub hot_nodes: u32,
    pub model: ModelKind,
    pub hidden: usize,
    pub seed: u64,
    /// Feature placement tier (`--tier host|gpu`); `Host` is the pre-tier
    /// single-buffer path. GPU tiering requires the shared buffer (it is
    /// incompatible with `--per-tenant-buffer`).
    pub tier: TierKind,
    /// GPU hot-tier capacity in bytes (`--gpu-mem`); required when
    /// `tier == Gpu`.
    pub gpu_mem: u64,
    /// UVM oversubscription ablation (`--gpu-oversub`).
    pub gpu_oversub: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            tenants: 4,
            workers: 2,
            requests: 200,
            rps: 0.0,
            clients: 4,
            admit_cap: 256,
            batch: BatchSpec {
                max_requests: 32,
                max_wait: Duration::from_millis(2),
            },
            fanouts: vec![10, 10],
            io_depth: 64,
            coalesce: CoalesceConfig::default(),
            buffer_mult: 4,
            per_tenant_buffer: false,
            serve_while_train: false,
            hot_nodes: 0,
            model: ModelKind::GraphSage,
            hidden: 64,
            seed: 17,
            tier: TierKind::Host,
            gpu_mem: 0,
            gpu_oversub: false,
        }
    }
}

/// Per-stage latency histograms of the serving pipeline. One sample per
/// *request* per stage (batch stages attribute their duration to every
/// member), so quantiles weight by request, not by batch.
#[derive(Clone, Debug, Default)]
pub struct StageHists {
    /// Arrival → claimed by a worker (queueing + batching linger).
    pub admission: LatencyHist,
    pub sample: LatencyHist,
    pub extract: LatencyHist,
    pub compute: LatencyHist,
    /// Arrival → response.
    pub total: LatencyHist,
}

impl StageHists {
    pub fn merge(&mut self, other: &StageHists) {
        self.admission.merge(&other.admission);
        self.sample.merge(&other.sample);
        self.extract.merge(&other.extract);
        self.compute.merge(&other.compute);
        self.total.merge(&other.total);
    }
}

/// Outcome of one serving run (or a merge of several).
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    /// Run wall time in sim units.
    pub wall: Duration,
    pub counts: AdmissionCounts,
    pub completed: u64,
    /// Requests answered with a typed I/O error after the engine retry
    /// policy gave up on their batch's extraction. Disjoint from both
    /// `completed` (useful responses) and `counts.shed` (refused at
    /// admission): shed ≠ error ≠ ok.
    pub errors: u64,
    pub batches: u64,
    pub stages: StageHists,
    /// Charged device reads / bytes / alignment overhead over the run
    /// (includes the concurrent trainer's I/O when enabled).
    pub ssd_read_requests: u64,
    pub ssd_read_bytes: u64,
    pub align_overhead_bytes: u64,
    /// Feature-buffer reuse deltas summed over all buffers:
    /// (hits, shared, steals, loads).
    pub buffer_hits: u64,
    pub buffer_shared: u64,
    pub buffer_steals: u64,
    pub buffer_loads: u64,
    /// Mini-batch steps the concurrent trainer completed.
    pub train_steps: u64,
    /// GPU-tier counters over the run (`--tier gpu`; `None` on the host
    /// path, whose report stays byte-identical to the pre-tier stack).
    pub tier: Option<TierSnapshot>,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            0.0
        } else {
            self.completed as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn mean_batch_fill(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }

    /// One-line run summary (the per-epoch report line).
    pub fn summary(&self) -> String {
        format!(
            "req {}/{} (shed {} err {})  batches {} (fill {:.1})  wall {}  {:.0} rps  e2e {}  extract p99 {}  ssd reqs {} ({})  fb hits {} loads {}{}",
            self.completed,
            self.counts.offered,
            self.counts.shed,
            self.errors,
            self.batches,
            self.mean_batch_fill(),
            crate::util::units::fmt_dur(self.wall),
            self.throughput_rps(),
            self.stages.total.summary(),
            crate::util::units::fmt_dur(self.stages.extract.p99()),
            self.ssd_read_requests,
            crate::util::units::fmt_bytes(self.ssd_read_bytes),
            self.buffer_hits,
            self.buffer_loads,
            if self.train_steps > 0 {
                format!("  train steps {}", self.train_steps)
            } else {
                String::new()
            },
        ) + &match &self.tier {
            Some(t) => {
                let mut s = format!(
                    "  tier gpu {}h/{}h  promo {}  demo {}  byp {}  saved {}",
                    t.gpu_hits,
                    t.host_hits,
                    t.promotions,
                    t.demotions,
                    t.bypassed,
                    crate::util::units::fmt_bytes(t.pcie_saved_bytes),
                );
                if t.oversub_faults > 0 {
                    s.push_str(&format!("  ovsub_faults {}", t.oversub_faults));
                }
                s
            }
            None => String::new(),
        }
    }

    /// Multi-line per-stage tail breakdown (the final summary).
    pub fn stage_detail(&self) -> String {
        format!(
            "  admission {}\n  sample    {}\n  extract   {}\n  compute   {}\n  total     {}",
            self.stages.admission.summary(),
            self.stages.sample.summary(),
            self.stages.extract.summary(),
            self.stages.compute.summary(),
            self.stages.total.summary(),
        )
    }

    /// Fold another run into this one (multi-epoch final summary).
    pub fn merge(&mut self, other: &ServeReport) {
        self.wall += other.wall;
        self.counts.offered += other.counts.offered;
        self.counts.admitted += other.counts.admitted;
        self.counts.shed += other.counts.shed;
        self.completed += other.completed;
        self.errors += other.errors;
        self.batches += other.batches;
        self.stages.merge(&other.stages);
        self.ssd_read_requests += other.ssd_read_requests;
        self.ssd_read_bytes += other.ssd_read_bytes;
        self.align_overhead_bytes += other.align_overhead_bytes;
        self.buffer_hits += other.buffer_hits;
        self.buffer_shared += other.buffer_shared;
        self.buffer_steals += other.buffer_steals;
        self.buffer_loads += other.buffer_loads;
        self.train_steps += other.train_steps;
        match (&mut self.tier, &other.tier) {
            (Some(mine), Some(theirs)) => mine.merge(theirs),
            (None, Some(theirs)) => self.tier = Some(*theirs),
            _ => {}
        }
    }
}

struct WorkerOutcome {
    hists: StageHists,
    completed: u64,
    errors: u64,
    batches: u64,
}

/// The long-lived serving engine bound to one machine + dataset. Buffers
/// persist across runs (a warm serving process keeps its cache warm).
pub struct ServeEngine {
    machine: Arc<Machine>,
    ds: Arc<Dataset>,
    cfg: ServeConfig,
    /// Shared padded caps per level — identical in shared and per-tenant
    /// modes (derived from the per-tenant share of the buffer budget), so
    /// the ablation compares I/O paths over identical sampled volume.
    caps: Vec<usize>,
    /// One shared buffer, or one per tenant under the ablation. Each holds
    /// at least `(workers + trainer + 1) × cap_L` slots (so blocking
    /// allocation always terminates even with every worker in one buffer
    /// group), times `buffer_mult` for cross-request residency.
    buffers: Vec<Arc<FeatureBuffer>>,
    /// Tiered placement store per buffer group (pure delegates in
    /// `--tier host`). GPU tiering runs only on the shared buffer, so at
    /// most `stores[0]` ever owns a device arena.
    stores: Vec<Arc<TieredFeatureStore>>,
}

impl ServeEngine {
    pub fn new(
        machine: &Arc<Machine>,
        ds: &Arc<Dataset>,
        cfg: ServeConfig,
    ) -> anyhow::Result<Self> {
        if cfg.fanouts.is_empty() {
            anyhow::bail!("serve needs at least one fanout level");
        }
        if cfg.requests == 0 {
            anyhow::bail!("serve needs --requests > 0");
        }
        let concurrent = cfg.workers.max(1) + usize::from(cfg.serve_while_train) + 1;
        // Derive caps from the per-tenant share of the buffer budget so the
        // per-tenant ablation (which must hold `tenants` buffers) and the
        // shared default get the same caps — identical per-request work.
        let budget = machine.host.capacity() / 4 / cfg.tenants.max(1) as u64;
        let caps = derive_caps(
            cfg.batch.max_requests.max(1),
            &cfg.fanouts,
            ds.spec.dim,
            budget,
            concurrent,
            1,
        );
        let cap_l = *caps.last().unwrap();
        // Liveness floor: every concurrent batch (all workers + the trainer
        // in one buffer group) must fit simultaneously with one spare, or
        // blocking allocation could never terminate. The multiplier buys
        // residency above that floor, clamped to the budget share.
        let floor = concurrent * cap_l;
        let budget_rows = (budget / (ds.spec.dim as u64 * 4)).max(1) as usize;
        let slots = (floor * cfg.buffer_mult.max(1)).min(budget_rows.max(floor));
        let n_buffers = if cfg.per_tenant_buffer { cfg.tenants.max(1) } else { 1 };
        let buffers = (0..n_buffers)
            .map(|_| {
                FeatureBuffer::in_host(&machine.host, slots, ds.spec.dim)
                    .map(Arc::new)
                    .map_err(anyhow::Error::new)
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        if cfg.tier == TierKind::Gpu && cfg.per_tenant_buffer {
            anyhow::bail!(
                "--tier gpu requires the shared feature buffer; \
                 it cannot combine with --per-tenant-buffer"
            );
        }
        let stores = buffers
            .iter()
            .map(|fb| match cfg.tier {
                TierKind::Host => Ok(TieredFeatureStore::host(fb.clone())),
                TierKind::Gpu => TieredFeatureStore::gpu(
                    fb.clone(),
                    &machine.devices[0],
                    machine.pcie.clone(),
                    cfg.gpu_mem,
                    TierPolicy {
                        oversub: cfg.gpu_oversub,
                        indptr: Some(ds.graph.indptr.clone()),
                        ..TierPolicy::default()
                    },
                )
                .map_err(anyhow::Error::new),
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ServeEngine { machine: machine.clone(), ds: ds.clone(), cfg, caps, buffers, stores })
    }

    pub fn caps(&self) -> &[usize] {
        &self.caps
    }

    pub fn buffers(&self) -> &[Arc<FeatureBuffer>] {
        &self.buffers
    }

    /// Tiered placement stores, parallel to [`ServeEngine::buffers`].
    pub fn stores(&self) -> &[Arc<TieredFeatureStore>] {
        &self.stores
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Build one extractor bound to buffer group `group`, with its own
    /// bounded staging arena (halved until the host reservation fits, like
    /// the training engine). Under `--tier gpu` the extractor plans through
    /// the group's tiered store.
    fn build_extractor(&self, group: usize) -> anyhow::Result<Extractor> {
        let fb = &self.buffers[group];
        let row_bytes = self.ds.features.row_bytes() as usize;
        let cap_l = *self.caps.last().unwrap();
        let mut staging_slots = cap_l.min(1024);
        let staging = loop {
            match StagingBuffer::new(&self.machine.host, staging_slots, row_bytes) {
                Ok(s) => break s,
                Err(_) if staging_slots > 256 => staging_slots /= 2,
                Err(e) => return Err(anyhow::Error::new(e)),
            }
        };
        let mut extractor = Extractor::with_options(
            self.machine.backend.clone(),
            self.cfg.io_depth,
            staging,
            fb.clone(),
            self.ds.features.clone(),
            // Serving gathers on the host for the forward pass, so the
            // buffer is host-resident and extraction skips the PCIe hop
            // (the paper's CPU-variant data path).
            ExtractTarget::Host,
            ExtractOptions {
                asynchronous: true,
                direct: true,
                coalesce: self.cfg.coalesce,
                hedge: HedgeConfig::disabled(),
            },
        );
        let store = &self.stores[group];
        if store.is_gpu() {
            extractor.set_tier(store.clone());
        }
        Ok(extractor)
    }

    /// Build one worker's extractor set: one extractor per buffer group.
    fn build_extractors(&self) -> anyhow::Result<Vec<Extractor>> {
        (0..self.buffers.len()).map(|g| self.build_extractor(g)).collect()
    }

    /// The serving compute step: the roofline cost model's forward-only
    /// charge (serving is a systems benchmark here, like every sweep). A
    /// PJRT-backed deployment would inject
    /// [`crate::runtime::TrainHandle`] through the same
    /// [`TrainStep::forward`] seam — its override routes to the eval-only
    /// artifact and never updates resident parameters.
    fn forward_step(&self) -> SimTrainStep {
        SimTrainStep::new(
            self.machine.cfg.gpu,
            self.machine.clock.clone(),
            self.cfg.model,
            self.caps.clone(),
            self.cfg.fanouts.clone(),
            self.ds.spec.dim,
            self.cfg.hidden,
            self.ds.spec.classes,
        )
    }

    /// One serving run: generate load, batch, serve, report. `epoch` salts
    /// the arrival/seed streams (and the concurrent trainer's plan).
    pub fn run(&self, epoch: u64) -> anyhow::Result<ServeReport> {
        let cfg = &self.cfg;
        let clock = &self.machine.clock;
        let skew = SeedSkew {
            nodes: self.ds.spec.nodes,
            hot: if cfg.hot_nodes == 0 { self.ds.spec.nodes } else { cfg.hot_nodes },
        };
        let seed = cfg.seed ^ (epoch << 24);
        let tenants = cfg.tenants.max(1);
        let groups = self.buffers.len();
        let per_tenant = cfg.per_tenant_buffer;

        // Pre-build every worker's extractor set (host reservations can
        // fail; surface that before any thread spawns).
        let mut extractor_sets = Vec::with_capacity(cfg.workers.max(1));
        for _ in 0..cfg.workers.max(1) {
            extractor_sets.push(self.build_extractors()?);
        }
        let trainer_ex = if cfg.serve_while_train {
            // The trainer shares buffer group 0 — with the default shared
            // buffer that is *the* buffer every serving worker uses.
            Some(self.build_extractor(0)?)
        } else {
            None
        };

        // The batcher's linger deadline is wall-clock (`Instant`) arithmetic,
        // but `--serve-wait` is specified in sim units like every other
        // latency in the system: convert here so batching behavior is
        // invariant under `GNNDRIVE_TIME_SCALE` compression.
        let batch_spec = BatchSpec {
            max_requests: cfg.batch.max_requests,
            max_wait: clock.to_real(cfg.batch.max_wait),
        };

        // Shared run state (declared outside the scope: scoped threads
        // borrow it for the whole scope lifetime).
        let adm = Admission::new(cfg.admit_cap);
        let batch_q = BoundedQueue::<InferBatch>::new(cfg.workers.max(1) * 2);
        let batch_seq = AtomicU64::new(0);
        let budget = AtomicU64::new(cfg.requests);
        let stop_train = AtomicBool::new(false);
        let train_steps = AtomicU64::new(0);

        let fb0: Vec<(u64, u64, u64, u64)> =
            self.buffers.iter().map(|fb| fb.stats()).collect();
        // Tier counters are cumulative across runs; take per-run deltas
        // (all-zero in host mode).
        let tier0 = self.stores[0].snapshot();
        let io_snap = EpochIoSnapshot::start(self.machine.backend.as_ref());
        let wall = Stopwatch::start(clock);

        let (outcomes, batches) = std::thread::scope(|s| {
            let worker_handles: Vec<_> = extractor_sets
                .into_iter()
                .enumerate()
                .map(|(w, exs)| {
                    let batch_q = &batch_q;
                    let batch_seq = &batch_seq;
                    s.spawn(move || self.serve_worker(w as u64 ^ seed, exs, batch_q, batch_seq))
                })
                .collect();

            let batcher = {
                let adm = &adm;
                let batch_q = &batch_q;
                let spec = batch_spec;
                s.spawn(move || {
                    run_batcher(adm, batch_q, spec, groups, move |t| {
                        if per_tenant {
                            t % groups
                        } else {
                            0
                        }
                    })
                })
            };

            let trainer_handle = trainer_ex.map(|ex| {
                let stop = &stop_train;
                let steps = &train_steps;
                s.spawn(move || self.train_loop(epoch, ex, stop, steps))
            });

            // ---- load generation ----
            if cfg.rps > 0.0 {
                run_open_loop(&adm, clock, skew, tenants, cfg.requests, cfg.rps, seed);
            } else {
                let clients: Vec<_> = (0..cfg.clients.max(1))
                    .map(|c| {
                        let adm = &adm;
                        let budget = &budget;
                        // Salt per client: two clients of one tenant are
                        // distinct callers, not replicas of one stream.
                        let client_seed = seed ^ ((c as u64 + 1) << 40);
                        s.spawn(move || {
                            run_closed_loop_client(adm, skew, c % tenants, budget, client_seed)
                        })
                    })
                    .collect();
                for c in clients {
                    let _ = c.join();
                }
            }
            // Drain: no new admissions; the batcher flushes the remainder
            // and closes the batch queue; workers exit once it is dry.
            adm.close();
            let outcomes: Vec<WorkerOutcome> =
                worker_handles.into_iter().map(|h| h.join().unwrap()).collect();
            let batches = batcher.join().unwrap();
            stop_train.store(true, Ordering::SeqCst);
            if let Some(t) = trainer_handle {
                t.join().unwrap();
            }
            (outcomes, batches)
        });

        // Converge queued demotions / deferred host evictions before the
        // buffer-reuse deltas are read (no-op in host mode).
        self.stores[0].quiesce();
        let tier = if self.stores[0].is_gpu() {
            Some(self.stores[0].snapshot().since(&tier0))
        } else {
            None
        };
        let wall = wall.elapsed();
        let io = io_snap.totals(self.machine.backend.as_ref());
        let mut stages = StageHists::default();
        let mut completed = 0u64;
        let mut errors = 0u64;
        for o in &outcomes {
            stages.merge(&o.hists);
            completed += o.completed;
            errors += o.errors;
        }
        let mut report = ServeReport {
            wall,
            counts: adm.counts(),
            completed,
            errors,
            batches,
            stages,
            ssd_read_requests: io.reads,
            ssd_read_bytes: io.read_bytes,
            align_overhead_bytes: io.align_overhead_bytes,
            train_steps: train_steps.into_inner(),
            tier,
            ..Default::default()
        };
        for (fb, before) in self.buffers.iter().zip(&fb0) {
            let (h, sh, st, ld) = fb.stats();
            report.buffer_hits += h - before.0;
            report.buffer_shared += sh - before.1;
            report.buffer_steals += st - before.2;
            report.buffer_loads += ld - before.3;
        }
        Ok(report)
    }

    /// One serving worker: claim formed batches, run sample → extract →
    /// forward, respond, release. Stage durations are attributed to every
    /// request of the batch; admission is measured per request.
    fn serve_worker(
        &self,
        seed: u64,
        extractors: Vec<Extractor>,
        batch_q: &BoundedQueue<InferBatch>,
        batch_seq: &AtomicU64,
    ) -> WorkerOutcome {
        state::register(Role::Server);
        let clock = &self.machine.clock;
        let dim = self.ds.spec.dim;
        let cap_l = *self.caps.last().unwrap();
        let sampler = Sampler::new(self.cfg.fanouts.clone(), seed ^ 0x5EB5E);
        let mut stepper = self.forward_step();
        let mut feats = vec![0f32; cap_l * dim];
        let mut seeds: Vec<u32> = Vec::with_capacity(self.cfg.batch.max_requests);
        let mut hists = StageHists::default();
        let mut completed = 0u64;
        let mut errors = 0u64;
        let mut batches = 0u64;

        while let Ok(batch) = batch_q.pop() {
            let t0 = Instant::now();
            for r in &batch.requests {
                hists
                    .admission
                    .record(clock.to_sim(t0.saturating_duration_since(r.arrival)));
            }
            // Dedup seeds, order-preserving (the sampler's label layout
            // requires unique seeds; duplicate requests share the rows).
            seeds.clear();
            for r in &batch.requests {
                if !seeds.contains(&r.seed) {
                    seeds.push(r.seed);
                }
            }
            let bid = batch_seq.fetch_add(1, Ordering::Relaxed);
            let sub =
                sampler.sample_batch(&self.ds, self.machine.backend.as_ref(), bid, &seeds);
            let padded = sub.pad(&self.caps, &self.cfg.fanouts);
            let t1 = Instant::now();

            let ex = &extractors[batch.group.min(extractors.len() - 1)];
            let aliases = match ex.try_extract(&padded.nodes[..padded.real_nodes]) {
                Ok(a) => a,
                Err(e) => {
                    // Graceful degradation: the engine retry policy already
                    // gave up on this batch's reads, so convert the batch
                    // into per-request typed error responses and keep
                    // serving — one bad sector must not take the frontend
                    // down. The degraded rows' refs are dropped here (the
                    // batch never reaches gather/release below).
                    let store = &self.stores[batch.group.min(self.stores.len() - 1)];
                    store.release_aliases(&e.aliases);
                    store.evict_if_idle(&e.failed_nodes);
                    for r in batch.requests {
                        errors += 1;
                        if let Some(done) = r.done {
                            let _ = done.send(Err(e.error.clone()));
                        }
                    }
                    batches += 1;
                    continue;
                }
            };
            let t2 = Instant::now();

            let store = &self.stores[batch.group.min(self.stores.len() - 1)];
            {
                let _busy = state::enter(state::State::Busy);
                store.gather(&aliases, &mut feats[..aliases.len() * dim]);
                feats[aliases.len() * dim..].fill(0.0);
            }
            let _ = stepper.forward(&padded, &feats);
            let t3 = Instant::now();
            store.release_aliases(&aliases);

            let (d_sample, d_extract, d_compute) = (
                clock.to_sim(t1 - t0),
                clock.to_sim(t2 - t1),
                clock.to_sim(t3 - t2),
            );
            let t_end = Instant::now();
            for r in batch.requests {
                hists.sample.record(d_sample);
                hists.extract.record(d_extract);
                hists.compute.record(d_compute);
                hists.total.record(clock.to_sim(t_end.saturating_duration_since(r.arrival)));
                completed += 1;
                if let Some(done) = r.done {
                    let _ = done.send(Ok(t_end));
                }
            }
            batches += 1;
        }
        state::deregister();
        WorkerOutcome { hists, completed, errors, batches }
    }

    /// Concurrent trainer (`--serve-while-train`): a single-threaded
    /// sample → extract → step loop over the train split, sharing buffer
    /// group 0 with the serving workers. Pure contention generator — its
    /// steps update the (simulated) model; it stops when serving drains.
    fn train_loop(
        &self,
        epoch: u64,
        extractor: Extractor,
        stop: &AtomicBool,
        steps: &AtomicU64,
    ) {
        state::register(Role::Trainer);
        let sampler = Sampler::new(self.cfg.fanouts.clone(), self.cfg.seed ^ 0x7EA1);
        let mut stepper = self.forward_step();
        let fb = &self.stores[0];
        let batch_size = self.caps[0];
        let mut inner_epoch = epoch;
        'outer: while !stop.load(Ordering::SeqCst) {
            let plan = EpochPlan::new(
                &self.ds.train_ids,
                batch_size,
                self.cfg.seed,
                inner_epoch,
                None,
            );
            while let Some((batch_id, seeds)) = plan.claim() {
                if stop.load(Ordering::SeqCst) {
                    break 'outer;
                }
                let sub = sampler.sample_batch(
                    &self.ds,
                    self.machine.backend.as_ref(),
                    batch_id,
                    seeds,
                );
                let padded = sub.pad(&self.caps, &self.cfg.fanouts);
                // The contention generator degrades like `--on-io-error
                // drop-rows`: a failed extraction releases its refs and
                // skips the step instead of killing the serving run.
                match extractor.try_extract(&padded.nodes[..padded.real_nodes]) {
                    Ok(aliases) => {
                        let _ = stepper.step(&padded, &[]);
                        fb.release_aliases(&aliases);
                        steps.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        fb.release_aliases(&e.aliases);
                        fb.evict_if_idle(&e.failed_nodes);
                    }
                }
            }
            inner_epoch += 1;
        }
        state::deregister();
    }
}
