//! Micro-batcher: groups admitted requests into inference batches.
//!
//! Admitted requests are pulled off the bounded admission queue and packed
//! into [`InferBatch`]es under two bounds: a batch closes as soon as it holds
//! `--serve-batch` requests (size bound) **or** as soon as its oldest member
//! has lingered `--serve-wait` in the batcher (latency bound) — the classic
//! size-or-deadline micro-batching contract. Batching is what turns N
//! single-seed requests into one sampled subgraph whose feature reads the
//! extractor's planner can coalesce into multi-row segments, so batch fill
//! directly buys I/O efficiency.
//!
//! Batches are keyed by *buffer group*: with one shared feature buffer all
//! tenants mix into the same batch (cross-tenant segment coalescing and
//! buffer reuse — the shared-tenancy win); under the per-tenant-buffer
//! ablation each tenant forms its own batches, because a batch can only
//! extract into one buffer. Ownership split with the admission layer: the
//! admission queue decides *whether* a request gets in (shed vs admit); the
//! batcher only decides *when* admitted requests execute.

use super::request::{Admission, InferRequest};
use crate::sim::queue::BoundedQueue;
use std::time::{Duration, Instant};

/// Size/linger bounds of one micro-batch.
#[derive(Clone, Copy, Debug)]
pub struct BatchSpec {
    /// Max requests per batch (`--serve-batch`).
    pub max_requests: usize,
    /// Max linger of the oldest member before a partial batch flushes
    /// (`--serve-wait`). `run_batcher` compares it against wall-clock
    /// `Instant`s; the serving engine converts its sim-unit config value to
    /// real time before handing the spec over, so linger behavior does not
    /// change under clock compression.
    pub max_wait: Duration,
}

/// One formed inference batch, bound to a buffer group.
pub struct InferBatch {
    /// Index into the serving engine's buffer list (0 when shared).
    pub group: usize,
    pub requests: Vec<InferRequest>,
}

struct Bucket {
    requests: Vec<InferRequest>,
    /// When the oldest member entered the batcher (linger clock).
    opened: Instant,
}

/// Drive the batcher until the admission queue is closed and drained, then
/// flush every partial bucket and close `out`. `group_of` maps a tenant to
/// its buffer group (identity under the per-tenant ablation, constant 0 when
/// shared). Returns the number of batches formed.
pub fn run_batcher(
    adm: &Admission,
    out: &BoundedQueue<InferBatch>,
    spec: BatchSpec,
    groups: usize,
    group_of: impl Fn(usize) -> usize,
) -> u64 {
    let max_requests = spec.max_requests.max(1);
    let mut buckets: Vec<Option<Bucket>> = (0..groups.max(1)).map(|_| None).collect();
    let mut formed = 0u64;

    let flush = |buckets: &mut Vec<Option<Bucket>>, g: usize, formed: &mut u64| {
        if let Some(b) = buckets[g].take() {
            *formed += 1;
            // Blocking push: a full batch queue is backpressure from the
            // serving workers, exactly like the pipeline's bounded queues.
            let _ = out.push(InferBatch { group: g, requests: b.requests });
        }
    };

    loop {
        // Nearest linger deadline across open buckets decides how long the
        // next pop may block.
        let deadline =
            buckets.iter().flatten().map(|b| b.opened + spec.max_wait).min();
        let popped = match deadline {
            None => match adm.pop() {
                Ok(r) => Some(r),
                Err(_) => break,
            },
            Some(dl) => {
                let now = Instant::now();
                if now >= dl {
                    None
                } else {
                    match adm.pop_timeout(dl - now) {
                        Ok(r) => r,
                        Err(_) => break,
                    }
                }
            }
        };
        match popped {
            Some(r) => {
                let g = group_of(r.tenant).min(buckets.len() - 1);
                let b = buckets[g].get_or_insert_with(|| Bucket {
                    requests: Vec::with_capacity(max_requests),
                    opened: Instant::now(),
                });
                b.requests.push(r);
                if b.requests.len() >= max_requests {
                    flush(&mut buckets, g, &mut formed);
                }
            }
            None => {
                // Linger expired somewhere: flush every overdue bucket.
                let now = Instant::now();
                for g in 0..buckets.len() {
                    if buckets[g]
                        .as_ref()
                        .is_some_and(|b| now >= b.opened + spec.max_wait)
                    {
                        flush(&mut buckets, g, &mut formed);
                    }
                }
            }
        }
    }
    // Admission closed and drained: flush the stragglers and end the stream.
    for g in 0..buckets.len() {
        flush(&mut buckets, g, &mut formed);
    }
    out.close();
    formed
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn req(tenant: usize, seed: u32) -> InferRequest {
        InferRequest { tenant, seed, arrival: Instant::now(), done: None }
    }

    fn spec(n: usize, wait_ms: u64) -> BatchSpec {
        BatchSpec { max_requests: n, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn size_bound_flushes_full_batches() {
        let adm = Admission::new(64);
        let out = Arc::new(BoundedQueue::<InferBatch>::new(16));
        for i in 0..10 {
            adm.submit(req(0, i)).unwrap();
        }
        adm.close();
        let formed = run_batcher(&adm, &out, spec(4, 1000), 1, |_| 0);
        assert_eq!(formed, 3, "10 requests at batch 4 → 4+4+2");
        let sizes: Vec<usize> = std::iter::from_fn(|| out.pop().ok())
            .map(|b| b.requests.len())
            .collect();
        assert_eq!(sizes, vec![4, 4, 2]);
    }

    #[test]
    fn linger_bound_flushes_partial_batches() {
        let adm = Arc::new(Admission::new(64));
        let out = Arc::new(BoundedQueue::<InferBatch>::new(16));
        let batcher = {
            let adm = adm.clone();
            let out = out.clone();
            std::thread::spawn(move || run_batcher(&adm, &out, spec(100, 10), 1, |_| 0))
        };
        adm.submit(req(0, 1)).unwrap();
        adm.submit(req(0, 2)).unwrap();
        // Far below the size bound: the linger deadline must flush.
        let b = out.pop().unwrap();
        assert_eq!(b.requests.len(), 2);
        adm.close();
        batcher.join().unwrap();
        assert!(out.pop().is_err(), "batcher closes its output");
    }

    #[test]
    fn groups_partition_batches_per_tenant() {
        let adm = Admission::new(64);
        let out = Arc::new(BoundedQueue::<InferBatch>::new(16));
        for i in 0..6 {
            adm.submit(req(i % 2, i as u32)).unwrap();
        }
        adm.close();
        // Per-tenant grouping: tenants 0 and 1 never share a batch.
        run_batcher(&adm, &out, spec(100, 1000), 2, |t| t);
        let mut batches: Vec<InferBatch> = std::iter::from_fn(|| out.pop().ok()).collect();
        batches.sort_by_key(|b| b.group);
        assert_eq!(batches.len(), 2);
        for b in &batches {
            assert_eq!(b.requests.len(), 3);
            assert!(b.requests.iter().all(|r| r.tenant == b.group));
        }
    }

    #[test]
    fn drain_flushes_all_open_buckets() {
        let adm = Admission::new(64);
        let out = Arc::new(BoundedQueue::<InferBatch>::new(16));
        adm.submit(req(0, 1)).unwrap();
        adm.submit(req(3, 2)).unwrap();
        adm.close();
        let formed = run_batcher(&adm, &out, spec(100, 10_000), 4, |t| t);
        assert_eq!(formed, 2, "close must flush partial buckets, not drop them");
    }
}
