//! Request layer of the serving frontend: per-tenant inference request
//! streams, the bounded admission queue, and the open-/closed-loop load
//! generators.
//!
//! The admission contract is the load-shedding one: the queue is *bounded*
//! and the open-loop entry point never blocks — when the queue is full the
//! request is **shed** (counted, dropped) instead of parked, so overload
//! degrades goodput rather than stretching every admitted request's queueing
//! delay unboundedly. Closed-loop clients use the blocking entry point: they
//! self-throttle by construction (one outstanding request per client), which
//! is how the generator models a fixed concurrency rather than a fixed rate.
//!
//! Seed-node popularity is shared across tenants ([`SeedSkew`]): every
//! tenant draws from the same skewed distribution over the node space, the
//! online-serving regime where cross-tenant reuse of hot embeddings is the
//! shared-buffer win the `serve` acceptance gate measures.

use crate::sim::queue::{BoundedQueue, Closed};
use crate::sim::Clock;
use crate::storage::IoError;
use crate::util::rng::Pcg;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Per-request response: completion instant, or the typed I/O error that
/// degraded the batch. An `Err` response is still a *response* — the request
/// was admitted and served; it is distinct from being shed at admission.
pub type InferResponse = Result<Instant, IoError>;

/// One online inference request: classify a single seed node on behalf of a
/// tenant's request stream.
pub struct InferRequest {
    pub tenant: usize,
    pub seed: u32,
    /// Arrival instant (real time; reports convert to sim units).
    pub arrival: Instant,
    /// Closed-loop completion signal carrying the response (completion
    /// instant or typed I/O error); open-loop requests carry `None` (nobody
    /// waits on them).
    pub done: Option<mpsc::Sender<InferResponse>>,
}

/// Shared seed-node popularity: a cubic-skew draw over the hot prefix
/// `[0, hot)` of the node space — a hot head around node 0 with a long cold
/// tail (the same shape the extraction bench's skewed workload uses; low
/// ids are also the generator's hub/community head, so hot seeds pull hot
/// neighborhoods). All tenants share one distribution — the online-serving
/// regime where popular entities are popular for everyone.
#[derive(Clone, Copy, Debug)]
pub struct SeedSkew {
    /// Node-space size (seeds never exceed it).
    pub nodes: u32,
    /// Prefix the draw concentrates on (`nodes` = skew over everything).
    pub hot: u32,
}

impl SeedSkew {
    /// Skew over the whole node space.
    pub fn over(nodes: u32) -> Self {
        SeedSkew { nodes, hot: nodes }
    }

    pub fn draw(&self, rng: &mut Pcg) -> u32 {
        let span = self.hot.clamp(1, self.nodes.max(1));
        let u = rng.f64();
        (((span as f64) * u * u * u) as u32).min(self.nodes - 1)
    }
}

/// Bounded admission queue with shed accounting. `offer` (open loop) never
/// blocks; `submit` (closed loop) does. Consumers are the micro-batcher.
pub struct Admission {
    queue: BoundedQueue<InferRequest>,
    offered: AtomicU64,
    admitted: AtomicU64,
    shed: AtomicU64,
}

/// Offered / admitted / shed counts at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AdmissionCounts {
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
}

impl Admission {
    pub fn new(cap: usize) -> Self {
        Admission {
            queue: BoundedQueue::new(cap.max(1)),
            offered: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    pub fn cap(&self) -> usize {
        self.queue.cap()
    }

    /// Open-loop entry: admit or shed, never block. Returns whether the
    /// request was admitted. Requests offered after `close` are shed too
    /// (a draining server refuses new work).
    pub fn offer(&self, req: InferRequest) -> bool {
        self.offered.fetch_add(1, Ordering::Relaxed);
        match self.queue.try_push(req) {
            Ok(()) => {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                true
            }
            Err(_) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// Closed-loop entry: block on backpressure (the client self-throttles).
    /// `Err(Closed)` once the server is draining — counted as shed so
    /// `offered == admitted + shed` holds on every path.
    pub fn submit(&self, req: InferRequest) -> Result<(), Closed> {
        self.offered.fetch_add(1, Ordering::Relaxed);
        match self.queue.push(req) {
            Ok(()) => {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(closed) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(closed)
            }
        }
    }

    /// Batcher side: blocking pop (drains the remainder after close).
    pub fn pop(&self) -> Result<InferRequest, Closed> {
        self.queue.pop()
    }

    /// Batcher side: pop with a linger deadline (see
    /// [`BoundedQueue::pop_timeout`]).
    pub fn pop_timeout(&self, timeout: Duration) -> Result<Option<InferRequest>, Closed> {
        self.queue.pop_timeout(timeout)
    }

    /// Stop admitting; queued requests still drain to the batcher.
    pub fn close(&self) {
        self.queue.close();
    }

    pub fn counts(&self) -> AdmissionCounts {
        AdmissionCounts {
            offered: self.offered.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
        }
    }
}

/// Open-loop generator: Poisson arrivals at `rps` (in *sim* time — the rate
/// the simulated device timings are calibrated against) for `total`
/// requests, round-robin across `tenants` streams with per-tenant seed
/// draws. Runs on the calling thread; returns when every arrival has been
/// offered (admitted or shed).
pub fn run_open_loop(
    adm: &Admission,
    clock: &Clock,
    skew: SeedSkew,
    tenants: usize,
    total: u64,
    rps: f64,
    seed: u64,
) {
    assert!(rps > 0.0, "open loop needs a positive --rps");
    let tenants = tenants.max(1);
    let mut rng = Pcg::with_stream(seed ^ 0x0BE2, 0x10AD);
    for i in 0..total {
        // Exponential inter-arrival: -ln(1-u)/λ, slept in sim units so the
        // offered rate and the device model share one clock.
        let u = rng.f64();
        let gap = -(1.0 - u).ln() / rps;
        clock.sleep(Duration::from_secs_f64(gap));
        let tenant = (i % tenants as u64) as usize;
        adm.offer(InferRequest {
            tenant,
            seed: skew.draw(&mut rng),
            arrival: Instant::now(),
            done: None,
        });
    }
}

/// One closed-loop client: a tenant's synchronous caller that keeps exactly
/// one request outstanding — submit, wait for completion, repeat — until the
/// shared budget runs out or the server drains. Returns the number of
/// requests this client completed. An `Err` response (I/O-degraded request)
/// still completes the call — the client got an answer, just not a useful
/// one — so the budget accounting is identical under fault storms.
pub fn run_closed_loop_client(
    adm: &Admission,
    skew: SeedSkew,
    tenant: usize,
    budget: &AtomicU64,
    seed: u64,
) -> u64 {
    let mut rng = Pcg::with_stream(seed ^ 0xC10_5ED, tenant as u64);
    let mut completed = 0u64;
    loop {
        // Claim one unit of the shared request budget.
        if budget
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| b.checked_sub(1))
            .is_err()
        {
            return completed;
        }
        let (tx, rx) = mpsc::channel();
        let req = InferRequest {
            tenant,
            seed: skew.draw(&mut rng),
            arrival: Instant::now(),
            done: Some(tx),
        };
        if adm.submit(req).is_err() {
            return completed; // server draining
        }
        if rx.recv().is_err() {
            return completed; // server dropped the request mid-drain
        }
        completed += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(tenant: usize) -> InferRequest {
        InferRequest { tenant, seed: 0, arrival: Instant::now(), done: None }
    }

    #[test]
    fn offer_sheds_when_full_and_counts_balance() {
        let adm = Admission::new(2);
        assert!(adm.offer(req(0)));
        assert!(adm.offer(req(1)));
        assert!(!adm.offer(req(2)), "third offer must shed, not block");
        let c = adm.counts();
        assert_eq!(c, AdmissionCounts { offered: 3, admitted: 2, shed: 1 });
        // Draining makes room; offers admit again.
        assert_eq!(adm.pop().unwrap().tenant, 0);
        assert!(adm.offer(req(3)));
        assert_eq!(adm.counts().shed, 1);
        // Post-close offers shed.
        adm.close();
        assert!(!adm.offer(req(4)));
        assert_eq!(adm.counts().shed, 2);
        // The admitted remainder still drains.
        assert_eq!(adm.pop().unwrap().tenant, 1);
        assert_eq!(adm.pop().unwrap().tenant, 3);
        assert!(adm.pop().is_err());
    }

    #[test]
    fn submit_blocks_instead_of_shedding() {
        let adm = std::sync::Arc::new(Admission::new(1));
        adm.submit(req(0)).unwrap();
        let adm2 = adm.clone();
        let h = std::thread::spawn(move || adm2.submit(req(1)));
        std::thread::sleep(Duration::from_millis(10));
        assert!(!h.is_finished(), "closed-loop submit must block, not shed");
        adm.pop().unwrap();
        h.join().unwrap().unwrap();
        assert_eq!(adm.counts().shed, 0);
    }

    #[test]
    fn seed_skew_is_hot_headed_and_in_range() {
        let skew = SeedSkew::over(10_000);
        let mut rng = Pcg::new(7);
        let draws: Vec<u32> = (0..4000).map(|_| skew.draw(&mut rng)).collect();
        assert!(draws.iter().all(|&d| d < 10_000));
        let hot = draws.iter().filter(|&&d| d < 1250).count(); // hottest eighth
        assert!(
            hot > draws.len() / 3,
            "cubic skew should concentrate mass at the head ({hot}/{})",
            draws.len()
        );
        // A hot prefix confines every draw while keeping the head hot.
        let confined = SeedSkew { nodes: 10_000, hot: 500 };
        let mut rng = Pcg::new(9);
        let draws: Vec<u32> = (0..1000).map(|_| confined.draw(&mut rng)).collect();
        assert!(draws.iter().all(|&d| d < 500), "draws must stay in the hot prefix");
    }

    #[test]
    fn closed_loop_budget_is_exact() {
        let adm = std::sync::Arc::new(Admission::new(16));
        let budget = std::sync::Arc::new(AtomicU64::new(10));
        let skew = SeedSkew::over(100);
        // A trivial in-line "server" completing everything.
        let server = {
            let adm = adm.clone();
            std::thread::spawn(move || {
                while let Ok(r) = adm.pop() {
                    if let Some(done) = r.done {
                        let _ = done.send(Ok(Instant::now()));
                    }
                }
            })
        };
        let clients: Vec<_> = (0..3)
            .map(|t| {
                let adm = adm.clone();
                let budget = budget.clone();
                std::thread::spawn(move || run_closed_loop_client(&adm, skew, t, &budget, 5))
            })
            .collect();
        let total: u64 = clients.into_iter().map(|c| c.join().unwrap()).sum();
        assert_eq!(total, 10, "exactly the shared budget completes");
        assert_eq!(adm.counts().admitted, 10);
        adm.close();
        server.join().unwrap();
    }
}
