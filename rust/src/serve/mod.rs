//! `serve` — the multi-tenant online-inference frontend (CLI `serve`).
//!
//! Layering, top to bottom:
//!
//! * [`request`] — per-tenant request streams, the **bounded admission
//!   queue** (open-loop offers shed on overload instead of queueing;
//!   closed-loop submits block), and the load generators (Poisson arrivals
//!   at `--rps`, or `--clients` synchronous callers).
//! * [`batcher`] — the **micro-batcher**: size-or-linger grouping
//!   (`--serve-batch` / `--serve-wait`) of admitted requests into inference
//!   batches, keyed by feature-buffer group so a batch always extracts into
//!   exactly one buffer.
//! * [`engine`] — the **serving engine**: workers drive each batch through
//!   the training stack's own sample → coalesced-extract → feature-buffer
//!   path and a read-only forward pass, all tenants sharing one
//!   [`crate::membuf::FeatureBuffer`] (the `--per-tenant-buffer` ablation
//!   splits them), optionally alongside a concurrent trainer
//!   (`--serve-while-train`). Per-stage latency lands in mergeable
//!   log-bucketed histograms ([`crate::util::stats::LatencyHist`]);
//!   [`ServeReport`] carries p50/p95/p99 per stage plus charged-I/O and
//!   buffer-reuse accounting.
//!
//! The subsystem is backend-agnostic (`--backend sim|os`): it only speaks
//! [`crate::storage::IoBackend`] through the sampler and extractor, exactly
//! like training. `benches/serve_latency.rs` tracks throughput/tail latency
//! and the shared-vs-per-tenant ablation in `BENCH_serve.json`.

pub mod batcher;
pub mod engine;
pub mod request;

pub use batcher::{BatchSpec, InferBatch};
pub use engine::{ServeConfig, ServeEngine, ServeReport, StageHists};
pub use request::{Admission, AdmissionCounts, InferRequest, InferResponse, SeedSkew};
