//! Minimal offline shim of the `anyhow` crate.
//!
//! The gnndrive build runs with no network and no crates.io mirror, so this
//! vendored stand-in implements exactly the surface the crate uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], and the [`Context`]
//! extension trait. Error values carry a display message plus an optional
//! boxed source; context wraps are flattened into the message chain.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// `anyhow::Result<T>`: a result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Dynamic error: a message and an optional boxed source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Wrap a concrete `std::error::Error` value.
    pub fn new<E: StdError + Send + Sync + 'static>(err: E) -> Self {
        Error { msg: err.to_string(), source: Some(Box::new(err)) }
    }

    /// Build an error from any displayable message.
    pub fn msg<M: Display + Send + Sync + 'static>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend a context line, keeping the original as the source chain.
    pub fn context<C: Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The outermost message (chain included, flattened).
    pub fn to_string_chain(&self) -> String {
        self.msg.clone()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.msg, f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src: Option<&(dyn StdError + 'static)> =
            self.source.as_deref().and_then(|s| s.source());
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        Error::new(err)
    }
}

/// Sealed helper so [`Context`] covers both plain `std` errors and
/// [`Error`] itself (which deliberately does not implement `std::error::Error`
/// to keep the blanket `From` impl coherent) — same trick as real anyhow.
mod ext {
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::new(self)
        }
    }
}

/// `.context(..)` / `.with_context(..)` on results.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: ext::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

/// `anyhow!("fmt", args...)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt", args...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io;

    fn io_fail() -> Result<()> {
        let r: std::result::Result<(), io::Error> =
            Err(io::Error::new(io::ErrorKind::NotFound, "gone"));
        r?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let r: Result<()> = io_fail().context("reading meta");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("reading meta: "), "{msg}");
    }

    #[test]
    fn with_context_on_anyhow_results_too() {
        let r: Result<()> = Err(anyhow!("base {}", 7));
        let msg = r.with_context(|| "outer").unwrap_err().to_string();
        assert_eq!(msg, "outer: base 7");
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: i32) -> Result<i32> {
            if x < 0 {
                bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(-1).unwrap_err().to_string().contains("negative"));
    }
}
