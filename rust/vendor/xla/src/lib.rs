//! Typed stub of the `xla` PJRT bindings.
//!
//! The real build links the XLA vendor set (PJRT CPU plugin + FFI
//! bindings). In offline containers that vendor set is absent, so this stub
//! provides the exact API surface `runtime/pjrt.rs` compiles against; every
//! entry point that would touch PJRT returns [`Error::Unavailable`].
//! `tests/runtime_roundtrip.rs` already skips itself when no artifacts are
//! built, so a stubbed runtime keeps `cargo test` green without hiding the
//! real integration behind a feature flag.

use std::fmt;

/// Error raised by every stubbed PJRT entry point.
#[derive(Debug, Clone)]
pub enum Error {
    /// The XLA vendor set is not linked into this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "xla stub: {what} requires the XLA vendor set (not linked)")
            }
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Host literal (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn scalar(_value: f32) -> Literal {
        Literal(())
    }

    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    pub fn get_first_element<T>(&self) -> Result<T> {
        unavailable("Literal::get_first_element")
    }
}

#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle returned by `execute` (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}
