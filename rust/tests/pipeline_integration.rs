//! Integration tests over the full GNNDrive pipeline: determinism, data
//! integrity through the stages, reordering behaviour, backpressure, and
//! the CPU variant's host-memory coupling.

use gnndrive::baselines::{shared_caps, sim_trainer};
use gnndrive::config::{Machine, MachineConfig, TrainConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::sample::{EpochPlan, Sampler};
use gnndrive::sim::Clock;
use std::sync::Arc;

fn serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn cfg() -> TrainConfig {
    TrainConfig {
        batch_size: 64,
        fanouts: vec![4, 4],
        batches_per_epoch: Some(5),
        samplers: 2,
        extractors: 2,
        io_depth: 32,
        ..TrainConfig::default()
    }
}

fn engine(machine: &Arc<Machine>, ds: &Arc<Dataset>, cfg: &TrainConfig) -> GnnDrive {
    let trainer = sim_trainer(machine, ds, cfg, ModelKind::GraphSage, Variant::Gpu, 64);
    GnnDrive::new(machine, ds, cfg.clone(), Variant::Gpu, trainer).unwrap()
}

#[test]
fn pipeline_extracts_exactly_the_sampled_rows() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let cfg = cfg();
    let e = engine(&machine, &ds, &cfg);
    machine.storage.direct_stats().useful_bytes.store(0, std::sync::atomic::Ordering::Relaxed);
    let stats = e.run_epoch(0);
    // Loads through the feature buffer equal direct-I/O requests (each
    // node's row fetched exactly once thanks to cross-extractor sharing).
    let (_, _, _, loads) = e.feature_buffer().stats();
    let reqs = machine
        .storage
        .direct_stats()
        .requests
        .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(loads, reqs, "every load is exactly one direct I/O request");
    assert!(stats.batches == 5);
}

#[test]
fn sampling_is_deterministic_across_engines() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    // Two identical samplers over the same plan produce identical batches.
    let ids = &ds.train_ids;
    let plan_a = EpochPlan::new(ids, 32, 9, 0, Some(4));
    let plan_b = EpochPlan::new(ids, 32, 9, 0, Some(4));
    let s = Sampler::new(vec![3, 3], 42);
    while let (Some((ia, a)), Some((ib, b))) = (plan_a.claim(), plan_b.claim()) {
        assert_eq!(ia, ib);
        assert_eq!(a, b);
        let sub_a = s.sample_batch(&ds, &machine.storage, ia, a);
        let sub_b = s.sample_batch(&ds, &machine.storage, ib, b);
        assert_eq!(sub_a.nodes, sub_b.nodes);
        assert_eq!(sub_a.labels, sub_b.labels);
    }
}

#[test]
fn reordering_occurs_with_parallel_stages_but_all_batches_train() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let mut c = cfg();
    c.batches_per_epoch = Some(12);
    c.samplers = 3;
    c.extractors = 3;
    let e = engine(&machine, &ds, &c);
    let expected = ds.train_ids.len().div_ceil(c.batch_size).min(12);
    let stats = e.run_epoch(0);
    assert_eq!(stats.batches, expected, "no batch may be lost to reordering");
    assert_eq!(stats.train.steps, expected);
    // (Inversions usually occur but are not guaranteed on 1 core; we only
    // require correctness, and surface the count for the curious.)
    eprintln!("observed {} inversions", stats.reorder_inversions);
}

#[test]
fn cpu_variant_feature_buffer_charges_host_memory() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let c = cfg();
    let before = machine.host.reserved();
    let trainer = sim_trainer(&machine, &ds, &c, ModelKind::GraphSage, Variant::Cpu, 64);
    let e = GnnDrive::new(&machine, &ds, c, Variant::Cpu, trainer).unwrap();
    let during = machine.host.reserved();
    assert!(
        during > before + (1 << 10),
        "CPU variant must hold the feature buffer in host memory"
    );
    assert_eq!(machine.devices[0].reserved(), 0);
    drop(e);
    assert_eq!(machine.host.reserved(), before);
}

#[test]
fn multi_epoch_runs_are_stable_and_release_slots() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let c = cfg();
    let e = engine(&machine, &ds, &c);
    for epoch in 0..3 {
        let st = e.run_epoch(epoch);
        assert_eq!(st.batches, 5, "epoch {epoch}");
        e.feature_buffer().check_invariants().unwrap();
    }
    // After every epoch finishes, all slots have zero refs.
    assert_eq!(e.feature_buffer().standby_len(), {
        // total slots = groups * cap_L
        let groups = c.train_queue_cap + c.extractors + 1;
        groups * e.caps().last().unwrap()
    });
}

#[test]
fn enforce_order_trains_in_batch_id_order() {
    let _s = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let mut c = cfg();
    c.enforce_order = true;
    c.samplers = 3;
    c.extractors = 3;
    c.batches_per_epoch = Some(8);
    let e = engine(&machine, &ds, &c);
    let expected = ds.train_ids.len().div_ceil(c.batch_size).min(8);
    let st = e.run_epoch(0);
    assert_eq!(st.batches, expected);
    assert_eq!(st.reorder_inversions, 0, "in-order mode must see zero inversions");
}

#[test]
fn padded_caps_respected_under_truncation() {
    let _s = serial();
    // CPU variant with a small host budget → caps truncate below the
    // no-dedup worst case, but shapes stay exact and nothing crashes.
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_host_mem(16 << 20),
        Clock::new(0.05),
    ));
    let mut spec = DatasetSpec::unit_test();
    spec.nodes = 30_000; // big enough that sampled prefixes exceed the caps
    let ds = Arc::new(Dataset::materialize(&spec, &machine).unwrap());
    let mut c = cfg();
    c.batch_size = 200;
    c.fanouts = vec![10, 10];
    let caps = shared_caps(&machine, &ds, &c, Variant::Cpu);
    let worst = 200 * (1 + 10 + 110);
    assert!(
        *caps.last().unwrap() < worst,
        "caps should be squeezed below worst {worst}: {caps:?}"
    );
    let trainer = sim_trainer(&machine, &ds, &c, ModelKind::GraphSage, Variant::Cpu, 64);
    let expected = ds.train_ids.len().div_ceil(200).min(5);
    let e = GnnDrive::new(&machine, &ds, c, Variant::Cpu, trainer).unwrap();
    let st = e.run_epoch(0);
    assert_eq!(st.batches, expected);
    assert!(st.truncated_edges > 0, "expected truncation at this budget");
}
