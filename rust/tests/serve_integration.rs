//! End-to-end tests of the `serve` subsystem: closed- and open-loop runs on
//! the sim backend, load shedding past the admission bound, the shared- vs
//! per-tenant-buffer ablation, concurrent serve+train tenancy, and an
//! os-backend smoke over a real tempdir dataset.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::serve::{BatchSpec, ServeConfig, ServeEngine};
use gnndrive::sim::Clock;
use gnndrive::storage::{BackendKind, IoBackend as _};
use std::sync::Arc;
use std::time::Duration;

fn sim_setup() -> (Arc<Machine>, Arc<Dataset>) {
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    (machine, ds)
}

fn quick_cfg() -> ServeConfig {
    ServeConfig {
        tenants: 2,
        workers: 2,
        requests: 60,
        clients: 3,
        admit_cap: 64,
        batch: BatchSpec { max_requests: 8, max_wait: Duration::from_millis(2) },
        fanouts: vec![4, 4],
        io_depth: 32,
        seed: 11,
        ..ServeConfig::default()
    }
}

/// After a run every buffer must be fully quiesced: zero leaked references
/// (all slots standby) and internally consistent.
fn assert_buffers_quiesced(engine: &ServeEngine) {
    for fb in engine.buffers() {
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), fb.n_slots, "slot references leaked");
    }
}

#[test]
fn closed_loop_completes_every_request() {
    let (machine, ds) = sim_setup();
    let engine = ServeEngine::new(&machine, &ds, quick_cfg()).unwrap();
    let report = engine.run(0).unwrap();
    assert_eq!(report.completed, 60, "closed loop must complete its whole budget");
    assert_eq!(report.counts.offered, 60);
    assert_eq!(report.counts.admitted, 60);
    assert_eq!(report.counts.shed, 0, "closed-loop submits block, never shed");
    assert!(report.batches > 0 && report.batches <= 60);
    assert!(report.mean_batch_fill() >= 1.0);
    // Every stage histogram saw one sample per request.
    for hist in [
        &report.stages.admission,
        &report.stages.sample,
        &report.stages.extract,
        &report.stages.compute,
        &report.stages.total,
    ] {
        assert_eq!(hist.count(), 60);
    }
    // End-to-end latency dominates each stage and quantiles are ordered.
    assert!(report.stages.total.p99() >= report.stages.extract.p50());
    assert!(report.stages.total.p50() <= report.stages.total.p99());
    assert!(report.wall > Duration::ZERO);
    assert!(report.throughput_rps() > 0.0);
    assert!(report.ssd_read_requests > 0, "inference must touch the SSD");
    assert!(report.buffer_loads > 0);
    assert_eq!(report.train_steps, 0);
    assert_buffers_quiesced(&engine);
}

#[test]
fn shared_buffer_turns_hot_nodes_into_cross_tenant_hits() {
    let (machine, ds) = sim_setup();
    let mut cfg = quick_cfg();
    cfg.requests = 200;
    cfg.tenants = 4;
    cfg.clients = 4;
    let engine = ServeEngine::new(&machine, &ds, cfg).unwrap();
    assert_eq!(engine.buffers().len(), 1, "default tenancy is one shared buffer");
    let report = engine.run(0).unwrap();
    assert_eq!(report.completed, 200);
    // The skewed seed distribution repeats hot nodes across tenants: the
    // shared buffer must serve a healthy share of them without I/O.
    assert!(
        report.buffer_hits > 0,
        "no cross-tenant reuse: hits {} loads {}",
        report.buffer_hits,
        report.buffer_loads
    );
    assert_buffers_quiesced(&engine);

    // A second epoch on the warm engine reuses resident rows.
    let again = engine.run(1).unwrap();
    assert!(
        again.buffer_hits > 0,
        "warm serving process must hit its resident rows"
    );
    assert_buffers_quiesced(&engine);
}

#[test]
fn open_loop_sheds_past_saturation_instead_of_queueing() {
    let (machine, ds) = sim_setup();
    let mut cfg = quick_cfg();
    // Arrivals far beyond service capacity against a tiny admission bound:
    // the overload must convert to shed requests, not an unbounded queue.
    cfg.requests = 300;
    cfg.rps = 200_000.0;
    cfg.admit_cap = 4;
    cfg.workers = 1;
    let engine = ServeEngine::new(&machine, &ds, cfg).unwrap();
    let report = engine.run(0).unwrap();
    assert_eq!(report.counts.offered, 300);
    assert!(report.counts.shed > 0, "past saturation the bounded queue must shed");
    assert_eq!(
        report.counts.admitted + report.counts.shed,
        report.counts.offered,
        "every offer either admits or sheds"
    );
    assert_eq!(
        report.completed, report.counts.admitted,
        "admitted requests are never dropped"
    );
    // Shedding bounds queueing: an admitted request waited at most
    // ~(cap + in-flight batches) service times, far below the whole run.
    assert!(report.stages.admission.p99() < report.wall);
    assert_buffers_quiesced(&engine);
}

#[test]
fn per_tenant_ablation_isolates_buffers_and_pays_more_io() {
    let (machine_shared, ds_shared) = sim_setup();
    let (machine_split, ds_split) = sim_setup();
    let mk = |per_tenant: bool| ServeConfig {
        requests: 240,
        tenants: 4,
        clients: 4,
        per_tenant_buffer: per_tenant,
        ..quick_cfg()
    };
    let shared = ServeEngine::new(&machine_shared, &ds_shared, mk(false)).unwrap();
    let split = ServeEngine::new(&machine_split, &ds_split, mk(true)).unwrap();
    assert_eq!(split.buffers().len(), 4, "one buffer per tenant under the ablation");
    assert_eq!(
        shared.caps(),
        split.caps(),
        "ablation must compare identical per-request work"
    );
    let r_shared = shared.run(0).unwrap();
    let r_split = split.run(0).unwrap();
    assert_eq!(r_shared.completed, 240);
    assert_eq!(r_split.completed, 240);
    // Hot rows are loaded once shared, once *per tenant* split: the shared
    // configuration must not load (or charge) more.
    assert!(
        r_shared.buffer_loads <= r_split.buffer_loads,
        "shared tenancy must not increase row loads ({} vs {})",
        r_shared.buffer_loads,
        r_split.buffer_loads
    );
    assert!(
        r_shared.ssd_read_requests <= r_split.ssd_read_requests,
        "shared tenancy must not charge more SSD requests ({} vs {})",
        r_shared.ssd_read_requests,
        r_split.ssd_read_requests
    );
    assert_buffers_quiesced(&shared);
    assert_buffers_quiesced(&split);
}

#[test]
fn serve_while_train_shares_one_buffer() {
    let (machine, ds) = sim_setup();
    let mut cfg = quick_cfg();
    cfg.requests = 80;
    cfg.serve_while_train = true;
    let engine = ServeEngine::new(&machine, &ds, cfg).unwrap();
    let report = engine.run(0).unwrap();
    assert_eq!(report.completed, 80, "training must not starve serving");
    assert!(
        report.train_steps > 0,
        "the concurrent trainer must make progress while serving"
    );
    // Trainer and servers shared one buffer and both released everything.
    assert_eq!(engine.buffers().len(), 1);
    assert_buffers_quiesced(&engine);
}

#[test]
fn os_backend_serves_from_real_files() {
    let dir = std::env::temp_dir().join(format!("gnndrive_serve_os_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = DatasetSpec::unit_test();
    Dataset::write_dir(&spec, &dir).unwrap();
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_backend(BackendKind::Os),
        Clock::new(1.0),
    ));
    assert_eq!(machine.backend.name(), "os");
    let ds = Arc::new(Dataset::load_dir(&dir, &machine).unwrap());
    let mut cfg = quick_cfg();
    cfg.requests = 30;
    let engine = ServeEngine::new(&machine, &ds, cfg).unwrap();
    let report = engine.run(0).unwrap();
    assert_eq!(report.completed, 30);
    assert_eq!(report.counts.shed, 0);
    assert!(report.ssd_read_requests > 0, "os backend must charge real reads");
    assert_buffers_quiesced(&engine);
    let _ = std::fs::remove_dir_all(&dir);
}
