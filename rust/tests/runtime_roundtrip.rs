//! Integration: AOT artifact (JAX/Pallas → HLO text) loads, compiles and
//! trains through the Rust PJRT runtime — the full L1/L2/L3 composition.
//!
//! Requires `make artifacts`; tests are skipped (pass vacuously, with a
//! stderr note) when the artifacts directory is absent so `cargo test`
//! stays green on a fresh checkout.

use gnndrive::runtime::{PjrtRuntime, PjrtTrainStep, TrainHandle};
use gnndrive::sample::{LayerAdj, SampledSubgraph};
use gnndrive::train::TrainStep;
use gnndrive::util::rng::Pcg;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("sage_mini.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Build a deterministic padded batch matching the sage_mini shapes
/// (caps 64/384/2048, fanouts 5/5, dim 64, 16 classes) with a planted
/// linear signal so training makes progress.
fn planted_batch(seed: u64, caps: &[usize], fanouts: &[usize], dim: usize) -> (gnndrive::sample::PaddedSubgraph, Vec<f32>) {
    let mut rng = Pcg::new(seed);
    let total = caps[caps.len() - 1];
    let classes = 16u32;
    // Node v's class:
    let class = |v: usize| (gnndrive::util::rng::hash2(7, v as u64) % classes as u64) as u32;
    let mut feats = vec![0f32; total * dim];
    for v in 0..total {
        let c = class(v);
        for j in 0..dim {
            let centroid = gnndrive::util::rng::hash_normal(99, (c as u64) * dim as u64 + j as u64);
            feats[v * dim + j] = centroid + 0.3 * gnndrive::util::rng::hash_normal(5, (v * dim + j) as u64);
        }
    }
    // Homophilous adjacency: neighbors of d share d's class.
    let mut adjs = Vec::new();
    for (i, &f) in fanouts.iter().enumerate() {
        let dst = caps[i];
        let hi = caps[i + 1];
        let mut idx = vec![-1i32; dst * f];
        for d in 0..dst {
            let want = class(d);
            for slot in 0..f {
                // Rejection-sample a same-class source.
                let mut s = rng.range(0, hi);
                for _ in 0..50 {
                    if class(s) == want {
                        break;
                    }
                    s = rng.range(0, hi);
                }
                idx[d * f + slot] = s as i32;
            }
        }
        adjs.push(LayerAdj { fanout: f, idx });
    }
    let labels: Vec<u16> = (0..caps[0]).map(|v| class(v) as u16).collect();
    let sub = SampledSubgraph {
        batch_id: 0,
        nodes: (0..total as u32).collect(),
        cum: caps.to_vec(),
        adjs,
        labels,
    };
    (sub.pad(caps, fanouts), feats)
}

#[test]
fn pjrt_loads_and_trains_sage_mini() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let mut step = PjrtTrainStep::load(&rt, &dir, "sage_mini").unwrap();
    assert_eq!(step.caps(), &[64, 384, 2048]);
    assert_eq!(step.dim(), 64);

    let (padded, feats) = planted_batch(3, &[64, 384, 2048], &[5, 5], 64);
    let first = step.step(&padded, &feats);
    assert!(first.loss.is_finite(), "loss={}", first.loss);
    assert_eq!(first.examples, 64);

    let mut last = first;
    for _ in 0..20 {
        last = step.step(&padded, &feats);
    }
    assert!(
        last.loss < first.loss * 0.8,
        "no training progress: {} -> {}",
        first.loss,
        last.loss
    );
    assert!(last.correct > first.correct || last.correct > 48);

    // Eval artifact agrees with the training forward pass direction.
    let eval = step.evaluate(&padded, &feats).unwrap();
    assert!(eval.loss.is_finite());
    assert!(eval.loss <= first.loss);
}

#[test]
fn all_three_model_artifacts_compile_and_train() {
    // GCN and GAT lower through the same Pallas kernels (gather_sum /
    // gather_rows); every artifact must load, run, and reduce its loss.
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    for name in ["gcn_mini", "gat_mini"] {
        if !dir.join(format!("{name}.hlo.txt")).exists() {
            eprintln!("skipping {name}: artifact not built");
            continue;
        }
        let mut step = PjrtTrainStep::load(&rt, &dir, name).unwrap();
        let (padded, feats) = planted_batch(7, &[64, 384, 2048], &[5, 5], 64);
        let first = step.step(&padded, &feats);
        assert!(first.loss.is_finite(), "{name}: loss={}", first.loss);
        let mut last = first;
        for _ in 0..15 {
            last = step.step(&padded, &feats);
        }
        assert!(
            last.loss < first.loss,
            "{name}: no progress {} -> {}",
            first.loss,
            last.loss
        );
    }
}

#[test]
fn train_service_is_send_and_persists_params() {
    let Some(dir) = artifacts_dir() else { return };
    let mut handle = TrainHandle::spawn(dir, "sage_mini".into()).unwrap();
    let (padded, feats) = planted_batch(11, &[64, 384, 2048], &[5, 5], 64);

    // Drive it from another thread (the pipeline's trainer does this).
    let first = handle.step(&padded, &feats);
    let losses: Vec<f32> = (0..6).map(|_| handle.step(&padded, &feats).loss).collect();
    assert!(losses.last().unwrap() < &first.loss, "{first:?} -> {losses:?}");
    assert!(handle.is_real());
    handle.shutdown();
}
