//! Integration: all five systems run the same workload on the same
//! substrate; sanity-check their relative behaviour and the OOM paths.

use gnndrive::baselines::{build_system, SystemKind};
use gnndrive::config::{Machine, MachineConfig, TrainConfig};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::sim::Clock;
use std::sync::Arc;

/// Timing-sensitive tests must not share the single CPU core: serialize.
fn serial() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn quick_cfg() -> TrainConfig {
    TrainConfig {
        batch_size: 64,
        fanouts: vec![4, 4],
        batches_per_epoch: Some(3),
        samplers: 2,
        extractors: 2,
        io_depth: 32,
        ..TrainConfig::default()
    }
}

#[test]
fn all_systems_complete_an_epoch() {
    let _serial = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    for kind in SystemKind::all() {
        let mut sys = build_system(kind, &machine, &ds, quick_cfg(), ModelKind::GraphSage)
            .unwrap_or_else(|e| panic!("{kind:?} build: {e}"));
        let stats = sys.run_epoch(0).unwrap_or_else(|e| panic!("{kind:?} epoch: {e}"));
        assert_eq!(stats.batches, 3, "{kind:?}");
        assert!(stats.epoch_time.as_nanos() > 0, "{kind:?}");
        assert!(stats.train.steps == 3, "{kind:?}");
        drop(sys);
        // Every system must fully release its host reservations (indptr
        // stays pinned by the dataset).
        assert_eq!(
            machine.host.reserved(),
            (ds.graph.indptr.len() * 8) as u64,
            "{kind:?} leaked host memory"
        );
    }
}

#[test]
fn sample_only_mode_works_for_comparables() {
    let _serial = serial();
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    for kind in [SystemKind::GnnDriveGpu, SystemKind::PygPlus, SystemKind::Ginex] {
        let mut sys =
            build_system(kind, &machine, &ds, quick_cfg(), ModelKind::GraphSage).unwrap();
        let t = sys.run_sample_only(0);
        assert!(t.as_nanos() > 0, "{kind:?}");
    }
}

#[test]
fn gnndrive_direct_io_vs_pygplus_page_cache() {
    let _serial = serial();
    // The architectural distinction the paper draws: PyG+ feature reads go
    // through the page cache; GNNDrive's use direct I/O.
    let machine = Arc::new(Machine::new(MachineConfig::paper(), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());

    let mut pyg =
        build_system(SystemKind::PygPlus, &machine, &ds, quick_cfg(), ModelKind::GraphSage)
            .unwrap();
    machine.storage.cache.stats().reset();
    pyg.run_epoch(0).unwrap();
    let feat_touches = machine
        .storage
        .cache
        .stats()
        .features
        .misses
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(feat_touches > 0, "PyG+ must touch feature pages");
    drop(pyg);

    let mut gd =
        build_system(SystemKind::GnnDriveGpu, &machine, &ds, quick_cfg(), ModelKind::GraphSage)
            .unwrap();
    machine.storage.cache.stats().reset();
    machine.storage.cache.drop_all();
    gd.run_epoch(0).unwrap();
    let feat_touches = machine
        .storage
        .cache
        .stats()
        .features
        .misses
        .load(std::sync::atomic::Ordering::Relaxed)
        + machine
            .storage
            .cache
            .stats()
            .features
            .hits
            .load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(feat_touches, 0, "GNNDrive feature reads must bypass the page cache");
}

#[test]
fn marius_oom_on_large_features_small_memory() {
    let _serial = serial();
    // MAG240M-like: dim 768 at a small host budget → OOM in preparation
    // (the Table 2 rows).
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_paper_host_gb(32),
        Clock::new(0.05),
    ));
    let mut spec = DatasetSpec::unit_test();
    spec.dim = 768;
    spec.nodes = 100_000;
    let ds = Arc::new(Dataset::materialize(&spec, &machine).unwrap());
    // feature bytes = 100k × 3 KiB ≈ 293 MiB; prep workspace 0.2× ≈ 59 MiB;
    // plus 76.8 MiB of partition buffers — exceeds 128 MiB → OOM at build
    // or inside prepare().
    let built = build_system(SystemKind::MariusGnn, &machine, &ds, quick_cfg(), ModelKind::GraphSage);
    match built {
        Err(e) => assert!(e.to_string().contains("OOM"), "{e}"),
        Ok(mut sys) => {
            let err = sys.run_epoch(0).err().expect("expected OOM");
            assert!(err.to_string().contains("OOM"), "{err}");
        }
    };
}

#[test]
fn pygplus_contention_slows_sampling() {
    let _serial = serial();
    // Fig 2's qualitative claim at unit-test scale: sampling within a full
    // SET epoch is slower than sampling alone, because feature pages evict
    // topology pages. Tight memory budget makes contention visible.
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_host_mem(8 << 20),
        Clock::new(0.1),
    ));
    let mut spec = DatasetSpec::unit_test();
    spec.nodes = 20_000;
    spec.dim = 512;
    let ds = Arc::new(Dataset::materialize(&spec, &machine).unwrap());
    // Single loader worker: on this 1-core testbed, multiple CPU-bound
    // samplers contend for the core and inflate summed sample time in the
    // `-only` condition; one worker isolates the page-cache effect, which
    // is what Fig 2 is about (DESIGN.md §3).
    let cfg = TrainConfig {
        batch_size: 128,
        fanouts: vec![8, 8],
        batches_per_epoch: Some(4),
        samplers: 1,
        extractors: 0,
        ..TrainConfig::default()
    };

    let mut pyg =
        build_system(SystemKind::PygPlus, &machine, &ds, cfg.clone(), ModelKind::GraphSage)
            .unwrap();
    // Warm the cache with a sample-only pass, then measure.
    pyg.run_sample_only(0);
    let only = pyg.run_sample_only(1);
    let all = pyg.run_epoch(1).unwrap();
    let ratio = all.sample_time.as_secs_f64() / only.as_secs_f64();
    assert!(
        ratio > 1.15,
        "expected sampling slowdown under contention, ratio={ratio:.2} ({:?} vs {only:?})",
        all.sample_time
    );
}
