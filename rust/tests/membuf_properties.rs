//! Property tests on the feature-buffer manager (paper Fig 6/Algorithm 1):
//! randomized begin/publish/release schedules across concurrent extractors
//! must preserve every structural invariant and never lose or duplicate
//! data.

use gnndrive::extract::coalesce::{plan_segments_striped, CoalesceConfig};
use gnndrive::graph::{FeatureGen, FeatureTable};
use gnndrive::membuf::FeatureBuffer;
use gnndrive::storage::{DataKind, DeviceMemory, FileId, StripeSpec};
use gnndrive::util::prop::{self, Config};
use gnndrive::util::rng::Pcg;
use std::sync::Arc;

fn make_fb(slots: usize, dim: usize) -> FeatureBuffer {
    let dev = DeviceMemory::new(1 << 30);
    FeatureBuffer::in_device(&dev, slots, dim).unwrap()
}

#[test]
fn random_schedules_preserve_invariants() {
    // A schedule is a list of batches (node sets); each batch goes through
    // begin -> publish(to_load) -> gather -> release, with interleavings
    // created by keeping several batches open at once.
    #[derive(Clone, Debug)]
    struct Schedule {
        slots: usize,
        batches: Vec<Vec<u32>>,
    }
    prop::check(
        Config::default().cases(60).sizes(2, 24),
        "feature buffer invariants under random schedules",
        |rng: &mut Pcg, size| {
            let batch_len = 1 + rng.below(8) as usize;
            // Slots must fit the max concurrently-open batches (3) per the
            // engine's sizing rule.
            let slots = 3 * batch_len + 1 + rng.below(8) as usize;
            let batches = (0..size)
                .map(|_| (0..batch_len).map(|_| rng.below(40)).collect::<Vec<u32>>())
                .map(|mut b| {
                    b.sort_unstable();
                    b.dedup();
                    b
                })
                .filter(|b| !b.is_empty())
                .collect();
            Schedule { slots, batches }
        },
        |s| {
            prop::shrink_vec(&s.batches)
                .into_iter()
                .map(|smaller| Schedule { slots: s.slots, batches: smaller })
                .collect()
        },
        |s| {
            if s.batches.is_empty() {
                return Ok(());
            }
            let fb = make_fb(s.slots, 4);
            // Keep up to 2 batches in flight (like extractors + train queue).
            let mut open: Vec<usize> = Vec::new();
            for (bi, batch) in s.batches.iter().enumerate() {
                let plan = fb.begin_batch(batch);
                for &(node, slot) in &plan.to_load {
                    let row: Vec<f32> = (0..4).map(|j| (node * 10 + j) as f32).collect();
                    fb.publish(node, slot, &row);
                }
                fb.wait_valid(&plan.wait_list);
                // Verify gathered data matches node identity (no slot mixups).
                let mut out = vec![0f32; batch.len() * 4];
                fb.gather(&plan.aliases, &mut out);
                for (i, &node) in batch.iter().enumerate() {
                    if out[i * 4] != (node * 10) as f32 {
                        return Err(format!(
                            "batch {bi}: node {node} row corrupted ({})",
                            out[i * 4]
                        ));
                    }
                }
                open.push(bi);
                fb.check_invariants()?;
                if open.len() > 2 {
                    let done_bi = open.remove(0);
                    fb.release(&s.batches[done_bi]);
                    fb.check_invariants()?;
                }
            }
            for bi in open {
                fb.release(&s.batches[bi]);
            }
            fb.check_invariants()?;
            // Everything released -> standby holds all slots.
            if fb.standby_len() != s.slots {
                return Err(format!(
                    "standby {} != slots {} after full release",
                    fb.standby_len(),
                    s.slots
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn concurrent_extractors_never_duplicate_loads() {
    // N threads extract overlapping node sets; total loads across all
    // threads must equal the number of distinct nodes (each row fetched
    // once — the sharing property of the wait list).
    prop::check_noshrink(
        Config::default().cases(12).sizes(4, 32),
        "no duplicate loads across concurrent extractors",
        |rng: &mut Pcg, size| {
            let sets: Vec<Vec<u32>> = (0..3)
                .map(|_| {
                    let mut v: Vec<u32> = (0..size).map(|_| rng.below(64)).collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect();
            sets
        },
        |sets| {
            let fb = Arc::new(make_fb(512, 2));
            let handles: Vec<_> = sets
                .iter()
                .cloned()
                .map(|set| {
                    let fb = fb.clone();
                    std::thread::spawn(move || {
                        let plan = fb.begin_batch(&set);
                        for &(node, slot) in &plan.to_load {
                            fb.publish(node, slot, &[node as f32, 0.0]);
                        }
                        fb.wait_valid(&plan.wait_list);
                        (set, plan.aliases)
                    })
                })
                .collect();
            let results: Vec<(Vec<u32>, Vec<i32>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let mut distinct: Vec<u32> =
                results.iter().flat_map(|(s, _)| s.iter().copied()).collect();
            distinct.sort_unstable();
            distinct.dedup();
            let (_, _, _, loads) = fb.stats();
            if loads as usize != distinct.len() {
                return Err(format!("{} loads for {} distinct nodes", loads, distinct.len()));
            }
            // All threads agree on aliases for shared nodes.
            for (set_a, al_a) in &results {
                for (set_b, al_b) in &results {
                    for (i, n) in set_a.iter().enumerate() {
                        if let Some(j) = set_b.iter().position(|m| m == n) {
                            if al_a[i] != al_b[j] {
                                return Err(format!("node {n} has two aliases"));
                            }
                        }
                    }
                }
            }
            for (set, _) in &results {
                fb.release(set);
            }
            fb.check_invariants()?;
            Ok(())
        },
    );
}

#[test]
fn plan_segments_emits_every_row_once_inside_one_stripe_chunk() {
    // The coalescing planner feeds the extractor's wave protocol *and* the
    // packed-layout path, so its conservation laws guard both: every input
    // row appears in exactly one segment at its true file offset, and no
    // segment ever grows past the stripe chunk owning its first byte —
    // over randomized row sets × {devices 1, 3} × coalescing on/off ×
    // staging capacities.
    const DIM: usize = 16; // 64-byte rows
    const ROW: usize = DIM * 4;
    const NODES: u32 = 4096;
    const CHUNK: u64 = 256; // 4 rows per stripe chunk (row-aligned)

    fn table() -> FeatureTable {
        let labels = Arc::new(vec![0u16; NODES as usize]);
        let gen = FeatureGen::new(1, DIM, 2, 0.1, labels);
        FeatureTable::procedural(FileId::new(78, DataKind::Features), NODES as u64, gen)
    }

    prop::check(
        Config::default().cases(60).sizes(1, 200),
        "plan_segments conservation + stripe-chunk containment",
        |rng: &mut Pcg, size| {
            let mut v: Vec<u32> = (0..size).map(|_| rng.below(NODES)).collect();
            v.sort_unstable();
            v.dedup();
            v
        },
        |ids| prop::shrink_vec(ids),
        |ids| {
            if ids.is_empty() {
                return Ok(());
            }
            let t = table();
            let to_load: Vec<(u32, u32)> =
                ids.iter().enumerate().map(|(i, &n)| (n, i as u32)).collect();
            let configs = [
                CoalesceConfig::disabled(),
                CoalesceConfig::default(),
                // Tight caps so span/gap limits actually bite at this scale.
                CoalesceConfig { max_bytes: 4 * ROW, gap_bytes: 2 * ROW },
            ];
            for devices in [1usize, 3] {
                let spec = StripeSpec::new(devices, CHUNK);
                for cfg in configs {
                    for capacity in [4 * ROW, 1 << 20] {
                        let segs = plan_segments_striped(&to_load, &t, &cfg, capacity, spec);
                        let what = format!(
                            "devices={devices} cfg={cfg:?} capacity={capacity} ids={ids:?}"
                        );
                        let mut seen: Vec<u32> = Vec::new();
                        for s in &segs {
                            if s.span < s.useful || s.span > capacity {
                                return Err(format!(
                                    "segment span {} vs useful {} cap {capacity}: {what}",
                                    s.span, s.useful
                                ));
                            }
                            if s.useful != s.rows.len() * ROW {
                                return Err(format!(
                                    "useful {} != {} rows * {ROW}: {what}",
                                    s.useful,
                                    s.rows.len()
                                ));
                            }
                            // CHUNK is a multiple of ROW, so even a single
                            // row can never straddle a chunk boundary here —
                            // the containment law holds unconditionally.
                            if s.offset + s.span as u64 > spec.chunk_end(s.offset) {
                                return Err(format!(
                                    "segment [{}, +{}) crosses chunk_end {}: {what}",
                                    s.offset,
                                    s.span,
                                    spec.chunk_end(s.offset)
                                ));
                            }
                            for r in &s.rows {
                                if s.offset + r.rel_off as u64 != t.row_offset(r.node as u64) {
                                    return Err(format!(
                                        "node {} placed at {}+{}: {what}",
                                        r.node, s.offset, r.rel_off
                                    ));
                                }
                                if to_load[r.slot as usize] != (r.node, r.slot) {
                                    return Err(format!(
                                        "row (node {}, slot {}) lost its pairing: {what}",
                                        r.node, r.slot
                                    ));
                                }
                                seen.push(r.node);
                            }
                        }
                        seen.sort_unstable();
                        if seen != *ids {
                            return Err(format!(
                                "planner emitted {} rows for {} inputs: {what}",
                                seen.len(),
                                ids.len()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}
