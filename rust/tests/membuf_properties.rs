//! Property tests on the feature-buffer manager (paper Fig 6/Algorithm 1):
//! randomized begin/publish/release schedules across concurrent extractors
//! must preserve every structural invariant and never lose or duplicate
//! data.

use gnndrive::membuf::FeatureBuffer;
use gnndrive::storage::DeviceMemory;
use gnndrive::util::prop::{self, Config};
use gnndrive::util::rng::Pcg;
use std::sync::Arc;

fn make_fb(slots: usize, dim: usize) -> FeatureBuffer {
    let dev = DeviceMemory::new(1 << 30);
    FeatureBuffer::in_device(&dev, slots, dim).unwrap()
}

#[test]
fn random_schedules_preserve_invariants() {
    // A schedule is a list of batches (node sets); each batch goes through
    // begin -> publish(to_load) -> gather -> release, with interleavings
    // created by keeping several batches open at once.
    #[derive(Clone, Debug)]
    struct Schedule {
        slots: usize,
        batches: Vec<Vec<u32>>,
    }
    prop::check(
        Config::default().cases(60).sizes(2, 24),
        "feature buffer invariants under random schedules",
        |rng: &mut Pcg, size| {
            let batch_len = 1 + rng.below(8) as usize;
            // Slots must fit the max concurrently-open batches (3) per the
            // engine's sizing rule.
            let slots = 3 * batch_len + 1 + rng.below(8) as usize;
            let batches = (0..size)
                .map(|_| (0..batch_len).map(|_| rng.below(40)).collect::<Vec<u32>>())
                .map(|mut b| {
                    b.sort_unstable();
                    b.dedup();
                    b
                })
                .filter(|b| !b.is_empty())
                .collect();
            Schedule { slots, batches }
        },
        |s| {
            prop::shrink_vec(&s.batches)
                .into_iter()
                .map(|smaller| Schedule { slots: s.slots, batches: smaller })
                .collect()
        },
        |s| {
            if s.batches.is_empty() {
                return Ok(());
            }
            let fb = make_fb(s.slots, 4);
            // Keep up to 2 batches in flight (like extractors + train queue).
            let mut open: Vec<usize> = Vec::new();
            for (bi, batch) in s.batches.iter().enumerate() {
                let plan = fb.begin_batch(batch);
                for &(node, slot) in &plan.to_load {
                    let row: Vec<f32> = (0..4).map(|j| (node * 10 + j) as f32).collect();
                    fb.publish(node, slot, &row);
                }
                fb.wait_valid(&plan.wait_list);
                // Verify gathered data matches node identity (no slot mixups).
                let mut out = vec![0f32; batch.len() * 4];
                fb.gather(&plan.aliases, &mut out);
                for (i, &node) in batch.iter().enumerate() {
                    if out[i * 4] != (node * 10) as f32 {
                        return Err(format!(
                            "batch {bi}: node {node} row corrupted ({})",
                            out[i * 4]
                        ));
                    }
                }
                open.push(bi);
                fb.check_invariants()?;
                if open.len() > 2 {
                    let done_bi = open.remove(0);
                    fb.release(&s.batches[done_bi]);
                    fb.check_invariants()?;
                }
            }
            for bi in open {
                fb.release(&s.batches[bi]);
            }
            fb.check_invariants()?;
            // Everything released -> standby holds all slots.
            if fb.standby_len() != s.slots {
                return Err(format!(
                    "standby {} != slots {} after full release",
                    fb.standby_len(),
                    s.slots
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn concurrent_extractors_never_duplicate_loads() {
    // N threads extract overlapping node sets; total loads across all
    // threads must equal the number of distinct nodes (each row fetched
    // once — the sharing property of the wait list).
    prop::check_noshrink(
        Config::default().cases(12).sizes(4, 32),
        "no duplicate loads across concurrent extractors",
        |rng: &mut Pcg, size| {
            let sets: Vec<Vec<u32>> = (0..3)
                .map(|_| {
                    let mut v: Vec<u32> = (0..size).map(|_| rng.below(64)).collect();
                    v.sort_unstable();
                    v.dedup();
                    v
                })
                .collect();
            sets
        },
        |sets| {
            let fb = Arc::new(make_fb(512, 2));
            let handles: Vec<_> = sets
                .iter()
                .cloned()
                .map(|set| {
                    let fb = fb.clone();
                    std::thread::spawn(move || {
                        let plan = fb.begin_batch(&set);
                        for &(node, slot) in &plan.to_load {
                            fb.publish(node, slot, &[node as f32, 0.0]);
                        }
                        fb.wait_valid(&plan.wait_list);
                        (set, plan.aliases)
                    })
                })
                .collect();
            let results: Vec<(Vec<u32>, Vec<i32>)> =
                handles.into_iter().map(|h| h.join().unwrap()).collect();
            let mut distinct: Vec<u32> =
                results.iter().flat_map(|(s, _)| s.iter().copied()).collect();
            distinct.sort_unstable();
            distinct.dedup();
            let (_, _, _, loads) = fb.stats();
            if loads as usize != distinct.len() {
                return Err(format!("{} loads for {} distinct nodes", loads, distinct.len()));
            }
            // All threads agree on aliases for shared nodes.
            for (set_a, al_a) in &results {
                for (set_b, al_b) in &results {
                    for (i, n) in set_a.iter().enumerate() {
                        if let Some(j) = set_b.iter().position(|m| m == n) {
                            if al_a[i] != al_b[j] {
                                return Err(format!("node {n} has two aliases"));
                            }
                        }
                    }
                }
            }
            for (set, _) in &results {
                fb.release(set);
            }
            fb.check_invariants()?;
            Ok(())
        },
    );
}
