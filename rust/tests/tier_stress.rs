//! Concurrency stress for the tiered feature store: ≥8 tenants hammer
//! begin_batch / publish / wait_plan / gather / release_aliases on a GPU
//! hot tier layered over a small, high-steal host buffer, with overlapping
//! skewed node sets. Mirrors `membuf_stress.rs`, one layer up: every
//! gather is content-checked (including rows served from the device
//! arena), and at quiesce points one thread settles the demotion queue and
//! validates the cross-tier structural invariants — zero leaked
//! references in either tier and no node resident in both.

use gnndrive::membuf::FeatureBuffer;
use gnndrive::sim::Clock;
use gnndrive::storage::{DeviceMemory, HostMemory, Pcie, PcieConfig};
use gnndrive::tier::{TierPolicy, TieredFeatureStore};
use gnndrive::util::rng::Pcg;
use std::sync::{Arc, Barrier};

const THREADS: usize = 8;
const BATCH: usize = 24;
const ITERS: u64 = 200;
const QUIESCE_EVERY: u64 = 50;
const DIM: usize = 4;
const ROW_BYTES: u64 = (DIM * 4) as u64;
/// Same engine sizing rule as the membuf stress: total live references
/// (THREADS × BATCH = 192) always fit, so blocking allocations terminate.
const SLOTS: usize = 256;
/// Node universe ~8× the host slot count: heavy steal + cross-tenant
/// sharing pressure underneath the tier.
const ID_SPACE: u32 = 2000;

fn pcie() -> Arc<Pcie> {
    // Effectively free transfers: this test asserts placement and
    // accounting, not time.
    Pcie::new(
        PcieConfig { bandwidth: 1e12, latency: std::time::Duration::ZERO, engines: 1 },
        Clock::new(1.0),
    )
}

fn gpu_store(fb_slots: usize, gpu_rows: u64) -> Arc<TieredFeatureStore> {
    let host = HostMemory::new(1 << 30);
    let fb = Arc::new(FeatureBuffer::in_host(&host, fb_slots, DIM).unwrap());
    let dev = DeviceMemory::new(1 << 30);
    TieredFeatureStore::gpu(fb, &dev, pcie(), gpu_rows * ROW_BYTES, TierPolicy::default())
        .unwrap()
}

/// Skewed per-tenant batches: half the draws from a shared hot head (so
/// promotions and GPU hits happen), half from the full id space (so the
/// host buffer steals and the tier demotes).
fn batch_for(thread: usize, iter: u64, hot: u32) -> Vec<u32> {
    let mut rng = Pcg::with_stream(0x71E4 + thread as u64, iter);
    let mut ids: Vec<u32> = (0..BATCH)
        .map(|k| if k % 2 == 0 { rng.below(hot) } else { rng.below(ID_SPACE) })
        .collect();
    ids.sort_unstable();
    ids.dedup();
    ids
}

/// One full batch lifecycle against the store, with content checks on
/// every row regardless of which tier served it (promotion copies the
/// published host row up, so the bytes must be identical).
fn run_checked_batch(store: &TieredFeatureStore, batch: &[u32], out: &mut [f32], tag: &str) {
    let plan = store.begin_batch(batch);
    for &(node, slot) in &plan.to_load {
        let row: Vec<f32> = (0..DIM).map(|j| (node * 10 + j as u32) as f32).collect();
        store.buffer().publish(node, slot, &row);
    }
    store.wait_plan(&plan);
    store.gather(&plan.aliases, &mut out[..batch.len() * DIM]);
    for (k, &node) in batch.iter().enumerate() {
        assert_eq!(out[k * DIM], (node * 10) as f32, "{tag}: node {node} row corrupted");
        assert_eq!(
            out[k * DIM + DIM - 1],
            (node * 10 + DIM as u32 - 1) as f32,
            "{tag}: node {node} row tail corrupted"
        );
    }
    store.release_aliases(&plan.aliases);
}

#[test]
fn concurrent_tiered_batches_stress() {
    // GPU tier big enough to hold the hot head, small against the full id
    // space: promotions, GPU hits, and clock-sweep demotions all happen
    // while the host buffer underneath steals constantly.
    let store = gpu_store(SLOTS, 128);
    let hot: u32 = 96;
    let quiesce = Barrier::new(THREADS);

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let store = store.clone();
            let quiesce = &quiesce;
            s.spawn(move || {
                let mut out = vec![0f32; BATCH * DIM];
                for i in 0..ITERS {
                    let batch = batch_for(t, i, hot);
                    run_checked_batch(&store, &batch, &mut out, &format!("thread {t} iter {i}"));
                    // Quiesce: everyone between release and next begin, one
                    // thread settles demotions and validates both tiers.
                    if (i + 1) % QUIESCE_EVERY == 0 {
                        quiesce.wait();
                        if t == 0 {
                            store.quiesce();
                            store.check_invariants().unwrap_or_else(|e| {
                                panic!("invariants broken at iter {i}: {e}")
                            });
                            store.check_exclusive().unwrap_or_else(|e| {
                                panic!("tier exclusivity broken at iter {i}: {e}")
                            });
                            // All batches released → zero refs on the host
                            // tier (GPU refs are checked by the sub_ref
                            // debug assertions on every release).
                            assert_eq!(
                                store.buffer().standby_len(),
                                SLOTS,
                                "host refcount leak at quiesce (iter {i})"
                            );
                        }
                        quiesce.wait();
                    }
                }
            });
        }
    });

    store.quiesce();
    store.check_invariants().unwrap();
    store.check_exclusive().unwrap();
    assert_eq!(store.buffer().standby_len(), SLOTS, "all host slots zero-ref after join");
    let snap = store.snapshot();
    assert!(snap.promotions > 0, "hot head must promote under this skew");
    assert!(snap.gpu_hits > 0, "promoted rows must serve later hits");
    assert!(snap.pcie_saved_bytes > 0, "GPU hits must bank saved transfers");
    let (_, _, steals, loads) = store.buffer().stats();
    assert!(loads > 0, "stress never loaded anything");
    assert!(steals > 0, "a {SLOTS}-slot host buffer over {ID_SPACE} ids must steal");
}

#[test]
fn multi_tenant_serving_tenants_share_one_tiered_store() {
    // The serving frontend's tenancy contract at the tier layer: N serving
    // tenants plus one cold-walking "trainer" share ONE tiered store. The
    // hot head must end up device-resident (promotions then GPU hits), the
    // tiny tier must churn (demotions), and after shutdown + quiesce there
    // must be zero leaked references and no dual-resident node.
    const SERVERS: usize = 7; // + 1 trainer below
    let store = gpu_store(SLOTS, 48); // tier smaller than the hot head: forced demotions
    let hot: u32 = 150;
    let quiesce = Barrier::new(SERVERS + 1);

    std::thread::scope(|s| {
        for t in 0..SERVERS + 1 {
            let store = store.clone();
            let quiesce = &quiesce;
            s.spawn(move || {
                let mut out = vec![0f32; BATCH * DIM];
                for i in 0..ITERS {
                    let batch = if t == SERVERS {
                        // The trainer walks the whole id space: pure churn.
                        let mut rng = Pcg::with_stream(0x7124 + t as u64, i);
                        let mut ids: Vec<u32> =
                            (0..BATCH).map(|_| rng.below(ID_SPACE)).collect();
                        ids.sort_unstable();
                        ids.dedup();
                        ids
                    } else {
                        batch_for(t, i, hot)
                    };
                    run_checked_batch(&store, &batch, &mut out, &format!("tenant {t} iter {i}"));
                    if (i + 1) % QUIESCE_EVERY == 0 {
                        quiesce.wait();
                        if t == 0 {
                            store.quiesce();
                            store.check_invariants().unwrap_or_else(|e| {
                                panic!("invariants broken at iter {i}: {e}")
                            });
                            store.check_exclusive().unwrap_or_else(|e| {
                                panic!("tier exclusivity broken at iter {i}: {e}")
                            });
                        }
                        quiesce.wait();
                    }
                }
            });
        }
    });

    store.quiesce();
    store.check_invariants().unwrap();
    store.check_exclusive().unwrap();
    assert_eq!(store.buffer().standby_len(), SLOTS, "host references leaked after shutdown");
    let snap = store.snapshot();
    assert!(snap.promotions > 0, "cross-tenant hot head must promote");
    assert!(snap.gpu_hits > 0, "tenants must share device-resident rows");
    assert!(
        snap.demotions > 0,
        "a 48-row tier under a {hot}-node hot head must demote (promotions {})",
        snap.promotions
    );
    assert_eq!(snap.oversub_faults, 0, "no oversubscription configured");
}
