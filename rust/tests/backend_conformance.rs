//! Backend conformance suite: every `IoBackend` must serve identical bytes,
//! account direct-I/O alignment identically, and drive the extractor's
//! two-phase wave protocol to the same results — whether the backend is the
//! simulated SSD stack or real OS files in a tempdir, and whether the
//! logical byte space is flat or RAID-0-striped across several devices.
//! Each check is a generic function run against every backend variant
//! (sim/os/uring × devices ∈ {1, 3}); the aggregate counters a check
//! observes must not depend on how many devices absorb the charges. The
//! uring column self-skips (with a printed reason) on kernels without
//! io_uring — the other columns still run.

use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{FeatureGen, FeatureTable};
use gnndrive::membuf::{FeatureBuffer, SlotRef, StagingArena, StagingBuffer};
use gnndrive::sim::Clock;
use gnndrive::storage::{
    AsyncIoEngine as _, Backing, BackingRef, DataKind, FileBacking, FileId, HostMemory,
    IoBackend, IoMode, MemBacking, OsFileBackend, PageCache, SimFile, Sqe, SsdConfig, SsdSim,
    Storage, StripeSpec, StripedBacking,
};
use std::sync::atomic::Ordering;
use std::sync::Arc;

const FILE_BYTES: usize = 64 * 1024;
/// Stripe chunk for the striped conformance variants: small enough that
/// the 64 KiB test file spans every device several times, sector-aligned
/// so chunk splits never amplify direct-I/O alignment.
const STRIPE: u64 = 4096;

fn pattern(i: usize) -> u8 {
    (i % 247) as u8
}

/// Unique path per call: tests in one binary run concurrently, so a shared
/// filename would let one test truncate a file another test's open
/// `FileBacking` is still reading.
fn unique_path(stem: &str) -> std::path::PathBuf {
    use std::sync::atomic::AtomicU32;
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let dir = std::env::temp_dir().join("gnndrive_conformance");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!(
        "{stem}_{}_{}.bin",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}

fn sim_backend(devices: usize) -> Arc<dyn IoBackend> {
    let clock = Clock::new(0.05);
    let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
    if devices == 1 {
        Arc::new(Storage::new(SsdSim::new(SsdConfig::pm883(), clock), cache))
    } else {
        let ssds = (0..devices)
            .map(|_| SsdSim::new(SsdConfig::pm883(), clock.clone()))
            .collect();
        Arc::new(Storage::new_striped(ssds, cache, STRIPE))
    }
}

fn os_backend(devices: usize) -> Arc<dyn IoBackend> {
    if devices == 1 {
        Arc::new(OsFileBackend::new(512))
    } else {
        Arc::new(OsFileBackend::with_stripe(512, 8, StripeSpec::new(devices, STRIPE)))
    }
}

fn uring_backend(devices: usize) -> Arc<dyn IoBackend> {
    let spec =
        if devices == 1 { StripeSpec::single() } else { StripeSpec::new(devices, STRIPE) };
    Arc::new(OsFileBackend::with_stripe_uring(512, 8, spec))
}

/// Whether the third conformance column (real io_uring) can run here; on
/// failure the reason is printed once so a skipped column is visible in the
/// test output rather than silently green.
fn uring_available() -> bool {
    match gnndrive::storage::probe_uring() {
        Ok(()) => true,
        Err(e) => {
            println!("SKIP: uring conformance column: no io_uring ({e})");
            false
        }
    }
}

/// Split a flat byte image into RAID-0 member images (`stripe`-sized chunks
/// round-robin across `devices`) — the reference layout every striped
/// backing must reassemble exactly.
fn stripe_split(bytes: &[u8], devices: usize, stripe: usize) -> Vec<Vec<u8>> {
    let mut members = vec![Vec::new(); devices];
    for (i, chunk) in bytes.chunks(stripe).enumerate() {
        members[i % devices].extend_from_slice(chunk);
    }
    members
}

/// In-memory striped backing over `bytes` (the sim-side striped data source).
fn striped_mem(bytes: &[u8], spec: StripeSpec) -> BackingRef {
    let members: Vec<BackingRef> = stripe_split(bytes, spec.devices, spec.stripe_bytes as usize)
        .into_iter()
        .map(|m| Arc::new(MemBacking::new(m)) as BackingRef)
        .collect();
    Arc::new(StripedBacking::new(members, spec.stripe_bytes))
}

/// Real-file striped backing over `bytes` (the os-side striped data source).
fn striped_files(stem: &str, bytes: &[u8], spec: StripeSpec) -> BackingRef {
    let members: Vec<BackingRef> = stripe_split(bytes, spec.devices, spec.stripe_bytes as usize)
        .into_iter()
        .enumerate()
        .map(|(d, m)| {
            let path = unique_path(&format!("{stem}_{d}"));
            std::fs::write(&path, &m).unwrap();
            Arc::new(FileBacking::open(&path).unwrap()) as BackingRef
        })
        .collect();
    Arc::new(StripedBacking::new(members, spec.stripe_bytes))
}

/// A patterned file for each backend: in-memory for sim, a real tempdir
/// file for os — byte-for-byte identical content; striped variants split
/// the same image across member backings matching the backend's geometry.
fn file_for(kind: &str, spec: StripeSpec) -> SimFile {
    let bytes: Vec<u8> = (0..FILE_BYTES).map(pattern).collect();
    let backing: BackingRef = match (kind, spec.is_striped()) {
        ("sim", false) => Arc::new(MemBacking::new(bytes)),
        ("sim", true) => striped_mem(&bytes, spec),
        // uring reads the same real files the pread backend does — only the
        // submission path differs.
        ("os" | "uring", false) => {
            let path = unique_path("data");
            std::fs::write(&path, &bytes).unwrap();
            Arc::new(FileBacking::open(&path).unwrap())
        }
        ("os" | "uring", true) => striped_files("data_striped", &bytes, spec),
        (other, _) => panic!("unknown backend {other}"),
    };
    SimFile::new(FileId::new(11, DataKind::Features), backing)
}

fn backends() -> Vec<(Arc<dyn IoBackend>, SimFile)> {
    let uring = uring_available();
    let mut v = Vec::new();
    for devices in [1usize, 3] {
        let spec = StripeSpec::new(devices, STRIPE);
        v.push((sim_backend(devices), file_for("sim", spec)));
        v.push((os_backend(devices), file_for("os", spec)));
        if uring {
            v.push((uring_backend(devices), file_for("uring", spec)));
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Read-back bytes
// ---------------------------------------------------------------------------

fn check_readback(io: &dyn IoBackend, file: &SimFile) {
    let name = io.name();
    for (off, len) in [(0usize, 512usize), (700, 100), (4095, 2), (1000, 4096)] {
        let mut direct = vec![0u8; len];
        io.read_direct(file, off as u64, &mut direct);
        let mut buffered = vec![0xFFu8; len];
        io.read_buffered(file, off as u64, &mut buffered);
        for (i, &b) in direct.iter().enumerate() {
            assert_eq!(b, pattern(off + i), "{name}: direct byte {off}+{i}");
        }
        assert_eq!(direct, buffered, "{name}: direct vs buffered at {off}+{len}");
    }
    // Past-end reads zero-fill identically.
    let mut tail = vec![0xAAu8; 64];
    io.read_direct(file, (FILE_BYTES - 32) as u64, &mut tail);
    for (i, &b) in tail.iter().take(32).enumerate() {
        assert_eq!(b, pattern(FILE_BYTES - 32 + i), "{name}: tail byte {i}");
    }
    assert!(tail[32..].iter().all(|&b| b == 0), "{name}: overhang must zero-fill");
}

#[test]
fn readback_bytes_identical_across_backends() {
    for (io, file) in backends() {
        check_readback(io.as_ref(), &file);
    }
}

// ---------------------------------------------------------------------------
// Alignment + counter accounting
// ---------------------------------------------------------------------------

fn check_alignment_accounting(io: &dyn IoBackend, file: &SimFile) {
    let name = io.name();
    assert_eq!(io.sector(), 512, "{name}");
    io.reset_io_stats();
    let base_requests = io.direct_stats().requests.load(Ordering::Relaxed);
    let base_useful = io.direct_stats().useful_bytes.load(Ordering::Relaxed);
    let base_aligned = io.direct_stats().aligned_bytes.load(Ordering::Relaxed);

    // 100 B at offset 700 fits in sector [512, 1024) → 512 aligned bytes.
    let mut buf = vec![0u8; 100];
    io.read_direct(file, 700, &mut buf);
    assert_eq!(
        io.direct_stats().requests.load(Ordering::Relaxed) - base_requests,
        1,
        "{name}: requests"
    );
    assert_eq!(
        io.direct_stats().useful_bytes.load(Ordering::Relaxed) - base_useful,
        100,
        "{name}: useful bytes"
    );
    assert_eq!(
        io.direct_stats().aligned_bytes.load(Ordering::Relaxed) - base_aligned,
        512,
        "{name}: aligned bytes"
    );
    assert_eq!(
        io.io_counters().reads.load(Ordering::Relaxed),
        1,
        "{name}: one charged read"
    );
    assert_eq!(
        io.io_counters().read_bytes.load(Ordering::Relaxed),
        512,
        "{name}: charged aligned volume"
    );

    // nocharge + charge_multi must land on the same totals as read_direct.
    let aligned = io.read_direct_nocharge(file, 1530, &mut buf); // spans 2 sectors
    assert_eq!(aligned, 1024, "{name}: 100B at 1530 spans [1024,2048)");
    assert_eq!(
        io.io_counters().reads.load(Ordering::Relaxed),
        1,
        "{name}: nocharge must not charge"
    );
    io.charge_multi(1, aligned);
    assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 2, "{name}");
    assert_eq!(
        io.io_counters().read_bytes.load(Ordering::Relaxed),
        512 + 1024,
        "{name}: coalesced charge equals per-op charge"
    );

    io.reset_io_stats();
    assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 0, "{name}: reset");
    assert_eq!(io.io_counters().read_bytes.load(Ordering::Relaxed), 0, "{name}: reset");
}

#[test]
fn alignment_accounting_identical_across_backends() {
    for (io, file) in backends() {
        check_alignment_accounting(io.as_ref(), &file);
    }
}

// ---------------------------------------------------------------------------
// Async engine contract
// ---------------------------------------------------------------------------

fn check_async_engine(io: Arc<dyn IoBackend>, file: &SimFile) {
    let name = io.name();
    io.reset_io_stats();
    let engine = io.clone().async_engine(8);
    const N: usize = 24;
    let arena = StagingArena::new(N, 512);
    let sqes: Vec<Sqe> = (0..N)
        .map(|i| Sqe {
            file: file.clone(),
            offset: (i * 512) as u64,
            len: 512,
            useful: 512,
            dst: SlotRef::new(arena.clone(), i),
            dst_off: 0,
            user_data: i as u64,
            mode: IoMode::Direct,
        })
        .collect();
    engine.submit_batch(sqes);
    let cqes = engine.wait_cqes(N);
    assert_eq!(cqes.len(), N, "{name}");
    let mut seen: Vec<u64> = cqes.iter().map(|c| c.user_data).collect();
    seen.sort_unstable();
    assert_eq!(seen, (0..N as u64).collect::<Vec<_>>(), "{name}: all CQEs");
    assert_eq!(engine.inflight(), 0, "{name}");
    assert_eq!(engine.pending_harvest(), 0, "{name}");
    for i in 0..N {
        let slot = SlotRef::new(arena.clone(), i);
        for (j, &b) in slot.bytes().iter().enumerate() {
            assert_eq!(b, pattern(i * 512 + j), "{name}: slot {i} byte {j}");
        }
    }
    // Aligned 512 B requests charge exactly their own volume on every
    // backend, coalesced or not.
    assert_eq!(
        io.io_counters().read_bytes.load(Ordering::Relaxed),
        (N * 512) as u64,
        "{name}: charged bytes"
    );
    assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), N as u64, "{name}");
}

#[test]
fn async_engines_complete_identically() {
    for (io, file) in backends() {
        check_async_engine(io, &file);
    }
}

// ---------------------------------------------------------------------------
// Engine drain (abort/early-exit shutdown ordering)
// ---------------------------------------------------------------------------

/// Regression for the shutdown-ordering hazard: an extraction that aborts
/// between submit and harvest leaves completions in flight whose
/// destinations are staging ranges the *next* wave reissues from cursor 0.
/// `drain` must quiesce the engine (wait out in-flight requests, discard
/// unharvested CQEs) so a late completion can never scatter into a recycled
/// range. Simulated here at the engine layer: submit a full wave, harvest
/// nothing (the abort), drain, then reuse the exact same arena ranges for
/// different reads and verify only the new bytes are present.
fn check_drain_quiesces_before_arena_reuse(io: Arc<dyn IoBackend>, file: &SimFile) {
    let name = io.name();
    let engine = io.clone().async_engine(8);
    const N: usize = 16;
    let arena = StagingArena::new(N, 512);

    // Drain on an idle engine is a no-op.
    engine.drain();
    assert_eq!(engine.inflight(), 0, "{name}");
    assert_eq!(engine.pending_harvest(), 0, "{name}");

    // "Aborted wave": submit N requests and never harvest their CQEs.
    let wave = |base: u64| -> Vec<Sqe> {
        (0..N as u64)
            .map(|i| Sqe {
                file: file.clone(),
                offset: base + i * 512,
                len: 512,
                useful: 512,
                dst: SlotRef::new(arena.clone(), i as usize),
                dst_off: 0,
                user_data: i,
                mode: IoMode::Direct,
            })
            .collect()
    };
    engine.submit_batch(wave(0));
    engine.drain();
    assert_eq!(engine.inflight(), 0, "{name}: drain must wait out in-flight requests");
    assert_eq!(engine.pending_harvest(), 0, "{name}: drain must swallow stale CQEs");

    // The recycled ranges now carry a *different* read each; after a normal
    // harvest every byte must come from the new offsets — stale bytes from
    // the aborted wave would differ (the pattern is offset-dependent).
    let base2 = 32 * 512u64;
    engine.submit_batch(wave(base2));
    let cqes = engine.wait_cqes(N);
    assert_eq!(cqes.len(), N, "{name}");
    assert_eq!(engine.pending_harvest(), 0, "{name}");
    for i in 0..N {
        let slot = SlotRef::new(arena.clone(), i);
        for (j, &b) in slot.bytes().iter().enumerate() {
            assert_eq!(
                b,
                pattern(base2 as usize + i * 512 + j),
                "{name}: slot {i} byte {j} holds stale pre-drain data"
            );
        }
    }
}

#[test]
fn drain_quiesces_engines_across_backends() {
    for (io, file) in backends() {
        check_drain_quiesces_before_arena_reuse(io, &file);
    }
}

/// The extractor applies the same discipline end to end: with a staging
/// arena far smaller than the batch, consecutive `extract` calls reissue
/// the same byte ranges across many waves (the entry drain is a no-op on
/// this clean path, but every wave boundary exercises the quiesce-then-
/// reuse protocol drain enforces for aborted paths), and every round's rows
/// must still decode exactly.
fn check_extractor_reuses_arena_cleanly(io: Arc<dyn IoBackend>) {
    let name = io.name();
    let labels = Arc::new((0..NODES as usize).map(|v| (v % 4) as u16).collect::<Vec<u16>>());
    let gen = FeatureGen::new(0xC0FFEE, DIM, 4, 0.3, labels);
    let features = features_for(io.as_ref(), &gen);
    let host = HostMemory::new(1 << 20);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, DIM).unwrap());
    // Staging far smaller than the batch: every extract runs many waves and
    // reissues the same ranges repeatedly.
    let staging = StagingBuffer::new(&host, 4, (DIM * 4) as usize).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        8,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions::default(),
    );
    for round in 0u32..3 {
        let nodes: Vec<u32> = (round * 40..round * 40 + 40).collect();
        let aliases = ex.extract(&nodes);
        let mut out = vec![0f32; DIM];
        let mut want = vec![0u8; DIM * 4];
        for (i, &v) in nodes.iter().enumerate() {
            fb.gather(&aliases[i..i + 1], &mut out);
            gen.fill_row(v as u64, &mut want);
            assert_eq!(out, FeatureGen::decode_row(&want), "{name}: round {round} node {v}");
        }
        fb.release_aliases(&aliases);
    }
    fb.check_invariants().unwrap();
}

#[test]
fn extractor_arena_reuse_conforms_across_backends() {
    for (io, _) in backends() {
        check_extractor_reuses_arena_cleanly(io);
    }
}

// ---------------------------------------------------------------------------
// Extractor wave behavior (async + sync fallback)
// ---------------------------------------------------------------------------

const DIM: usize = 16;
const NODES: u64 = 200;

fn features_for(io: &dyn IoBackend, gen: &FeatureGen) -> FeatureTable {
    let spec = io.stripe();
    let backing: BackingRef = match (io.name(), spec.is_striped()) {
        ("sim", false) => {
            return FeatureTable::procedural(
                FileId::new(21, DataKind::Features),
                NODES,
                gen.clone(),
            )
        }
        ("sim", true) => {
            // Materialize the rows flat, then stripe-split into in-memory
            // members — identical logical bytes to the procedural table.
            let row = gen.row_bytes() as usize;
            let mut bytes = vec![0u8; NODES as usize * row];
            for v in 0..NODES {
                gen.fill_row(v, &mut bytes[v as usize * row..(v as usize + 1) * row]);
            }
            striped_mem(&bytes, spec)
        }
        ("os" | "uring", false) => {
            let path = unique_path("features");
            FeatureTable::write_file(&path, NODES, gen).unwrap();
            Arc::new(FileBacking::open(&path).unwrap())
        }
        ("os" | "uring", true) => {
            // Exercise the production striped writer end to end.
            let paths: Vec<std::path::PathBuf> =
                (0..spec.devices).map(|d| unique_path(&format!("features_{d}"))).collect();
            FeatureTable::write_file_striped(&paths, NODES, gen, spec.stripe_bytes).unwrap();
            let members: Vec<BackingRef> = paths
                .iter()
                .map(|p| Arc::new(FileBacking::open(p).unwrap()) as BackingRef)
                .collect();
            Arc::new(StripedBacking::new(members, spec.stripe_bytes))
        }
        (other, _) => panic!("unknown backend {other}"),
    };
    FeatureTable::from_backing(FileId::new(21, DataKind::Features), NODES, DIM, backing)
}

fn check_extractor_waves(io: Arc<dyn IoBackend>, asynchronous: bool) {
    let name = io.name();
    let labels = Arc::new((0..NODES as usize).map(|v| (v % 4) as u16).collect::<Vec<u16>>());
    let gen = FeatureGen::new(0xC0FFEE, DIM, 4, 0.3, labels);
    let features = features_for(io.as_ref(), &gen);
    let host = HostMemory::new(1 << 20);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, DIM).unwrap());
    // 8 staging slots against 60 nodes → the extractor must run in waves.
    let staging = StagingBuffer::new(&host, 8, (DIM * 4) as usize).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        16,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        // Coalescing disabled: this check pins the per-row wave protocol
        // and its exact per-row charge parity across backends; the
        // coalescing suite below covers the merged path.
        ExtractOptions { asynchronous, coalesce: CoalesceConfig::disabled(), ..Default::default() },
    );
    io.reset_io_stats();
    let nodes: Vec<u32> = (30..90).collect();
    let aliases = ex.extract(&nodes);
    assert_eq!(aliases.len(), 60, "{name}");
    assert!(aliases.iter().all(|&a| a >= 0), "{name}");
    let mut out = vec![0f32; DIM];
    let mut want = vec![0u8; DIM * 4];
    for (i, &v) in nodes.iter().enumerate() {
        fb.gather(&aliases[i..i + 1], &mut out);
        gen.fill_row(v as u64, &mut want);
        assert_eq!(out, FeatureGen::decode_row(&want), "{name}: node {v}");
    }
    // Every row was loaded exactly once, each a 64 B read inside one 512 B
    // sector → identical charged volume on both backends.
    assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 60, "{name}: loads");
    assert_eq!(
        io.io_counters().read_bytes.load(Ordering::Relaxed),
        60 * 512,
        "{name}: aligned charges"
    );
    // Re-extraction is served from the feature buffer: zero new I/O.
    io.reset_io_stats();
    let again = ex.extract(&nodes);
    assert_eq!(again, aliases, "{name}: resident rows keep their slots");
    assert_eq!(io.io_counters().reads.load(Ordering::Relaxed), 0, "{name}: buffer hit");
    fb.check_invariants().unwrap();
}

#[test]
fn extractor_waves_conform_async() {
    for (io, _) in backends() {
        check_extractor_waves(io, true);
    }
}

#[test]
fn extractor_waves_conform_sync_fallback() {
    for (io, _) in backends() {
        check_extractor_waves(io, false);
    }
}

// ---------------------------------------------------------------------------
// Segment coalescing
// ---------------------------------------------------------------------------

/// Run one extraction of `nodes` under `coalesce` on a fresh feature buffer;
/// returns (gathered rows, charged reads, charged bytes, useful, aligned).
fn run_extraction(
    io: &Arc<dyn IoBackend>,
    nodes: &[u32],
    staging_slots: usize,
    coalesce: CoalesceConfig,
) -> (Vec<f32>, u64, u64, u64, u64) {
    let labels = Arc::new((0..NODES as usize).map(|v| (v % 4) as u16).collect::<Vec<u16>>());
    let gen = FeatureGen::new(0xC0FFEE, DIM, 4, 0.3, labels);
    let features = features_for(io.as_ref(), &gen);
    let host = HostMemory::new(1 << 20);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, DIM).unwrap());
    let staging = StagingBuffer::new(&host, staging_slots, (DIM * 4) as usize).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        16,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions { coalesce, ..Default::default() },
    );
    io.reset_io_stats();
    let dio = io.direct_stats().snapshot();
    let aliases = ex.extract(nodes);
    let reads = io.io_counters().reads.load(Ordering::Relaxed);
    let bytes = io.io_counters().read_bytes.load(Ordering::Relaxed);
    let (useful, aligned) = io.direct_stats().snapshot();
    let mut rows = vec![0f32; nodes.len() * DIM];
    fb.gather(&aliases, &mut rows);
    fb.check_invariants().unwrap();
    (rows, reads, bytes, useful - dio.0, aligned - dio.1)
}

/// Coalescing on vs off: identical read-back bytes, strictly fewer charged
/// requests, `aligned_bytes ≤` the uncoalesced run, identical useful bytes —
/// on both backends.
fn check_coalescing_parity(io: Arc<dyn IoBackend>) {
    let name = io.name();
    let nodes: Vec<u32> = (30..94).collect(); // 64 dense 64-byte rows
    let (rows_off, reads_off, bytes_off, useful_off, aligned_off) =
        run_extraction(&io, &nodes, 64, CoalesceConfig::disabled());
    let (rows_on, reads_on, bytes_on, useful_on, aligned_on) =
        run_extraction(&io, &nodes, 64, CoalesceConfig::default());

    assert_eq!(rows_on, rows_off, "{name}: extracted bytes must be identical");
    assert_eq!(reads_off, 64, "{name}: baseline issues one request per row");
    assert!(
        reads_on < reads_off,
        "{name}: coalescing must charge strictly fewer requests ({reads_on} vs {reads_off})"
    );
    assert!(
        reads_on * 2 <= reads_off,
        "{name}: dense rows must merge ≥2× ({reads_on} vs {reads_off})"
    );
    assert_eq!(useful_on, useful_off, "{name}: useful bytes are coalescing-independent");
    assert_eq!(useful_on, (nodes.len() * DIM * 4) as u64, "{name}: useful = row bytes");
    assert!(
        aligned_on <= aligned_off,
        "{name}: dense coalescing must not amplify ({aligned_on} vs {aligned_off})"
    );
    assert!(
        bytes_on <= bytes_off,
        "{name}: charged volume must not grow on dense rows ({bytes_on} vs {bytes_off})"
    );
}

#[test]
fn coalescing_parity_across_backends() {
    for (io, _) in backends() {
        check_coalescing_parity(io);
    }
}

/// Gap boundary: rows exactly `coalesce-gap` apart must NOT merge (the gap
/// bound is strict), and rows one byte closer must.
fn check_gap_boundary(io: Arc<dyn IoBackend>) {
    let name = io.name();
    let row = DIM * 4; // 64
    // Every 4th node: the gap between consecutive rows is 3 rows = 192 B.
    let nodes: Vec<u32> = (0..20).map(|i| i * 4).collect();
    let gap = 3 * row;

    let at_gap = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: gap };
    let (_, reads, _, _, _) = run_extraction(&io, &nodes, 64, at_gap);
    assert_eq!(
        reads,
        nodes.len() as u64,
        "{name}: rows exactly coalesce-gap apart must not merge"
    );

    let over_gap = CoalesceConfig { max_bytes: 1 << 20, gap_bytes: gap + 1 };
    let (_, reads, _, _, _) = run_extraction(&io, &nodes, 64, over_gap);
    assert!(
        reads < nodes.len() as u64,
        "{name}: rows within coalesce-gap must merge ({reads} requests)"
    );
}

#[test]
fn gap_boundary_conforms_across_backends() {
    for (io, _) in backends() {
        check_gap_boundary(io);
    }
}

// ---------------------------------------------------------------------------
// Stripe address-translation edge cases
// ---------------------------------------------------------------------------

/// Both striped backing flavors (in-memory members, real-file members) over
/// the same flat image — translation bugs would diverge from the pattern.
fn striped_backings(stem: &str, bytes: &[u8], devices: usize, stripe: u64) -> Vec<BackingRef> {
    let spec = StripeSpec::new(devices, stripe);
    vec![striped_mem(bytes, spec), striped_files(stem, bytes, spec)]
}

fn assert_pattern(backing: &dyn Backing, off: usize, len: usize, what: &str) {
    let mut buf = vec![0xEEu8; len];
    backing.read_at(off as u64, &mut buf);
    for (i, &b) in buf.iter().enumerate() {
        assert_eq!(b, pattern(off + i), "{what}: byte {off}+{i}");
    }
}

#[test]
fn stripe_rows_on_chunk_boundaries_translate_exactly() {
    let bytes: Vec<u8> = (0..FILE_BYTES).map(pattern).collect();
    for backing in striped_backings("edge_boundary", &bytes, 3, STRIPE) {
        let s = STRIPE as usize;
        // A row starting exactly on a chunk boundary lives wholly on the
        // next device; one ending exactly on a boundary never touches it.
        assert_pattern(backing.as_ref(), s, 64, "row starts on boundary");
        assert_pattern(backing.as_ref(), s - 64, 64, "row ends on boundary");
        // A row straddling the boundary splits across two devices.
        assert_pattern(backing.as_ref(), s - 10, 20, "row straddles boundary");
        // Device wrap-around: chunk 2 → device 2, chunk 3 → device 0 again.
        assert_pattern(backing.as_ref(), 3 * s - 10, 20, "wrap to device 0");
    }
}

#[test]
fn stripe_read_wider_than_one_chunk_spans_devices() {
    let bytes: Vec<u8> = (0..FILE_BYTES).map(pattern).collect();
    for backing in striped_backings("edge_wide", &bytes, 3, STRIPE) {
        // One read wider than a whole stripe of chunks: covers every device
        // at least once and re-enters device 0 (4 chunk splits from one
        // logical range).
        assert_pattern(backing.as_ref(), 100, 3 * STRIPE as usize + 123, "multi-chunk read");
        // Whole-file read reassembles the image exactly.
        assert_pattern(backing.as_ref(), 0, FILE_BYTES, "whole image");
    }
}

#[test]
fn stripe_last_partial_chunk_and_eof_zero_fill() {
    // 2 full chunks + a 1808-byte tail: member lengths are unequal
    // (4096, 4096, 1808) and the logical EOF sits mid-chunk on device 2.
    let n = 2 * STRIPE as usize + 1808;
    let bytes: Vec<u8> = (0..n).map(pattern).collect();
    for backing in striped_backings("edge_tail", &bytes, 3, STRIPE) {
        assert_eq!(backing.len(), n as u64, "member lengths sum to the logical size");
        assert_pattern(backing.as_ref(), n - 1808, 1808, "partial tail chunk");
        // A read crossing logical EOF returns the tail then zero-fills,
        // exactly like a flat backing.
        let mut buf = vec![0xAAu8; 2048];
        backing.read_at((n - 1000) as u64, &mut buf);
        for (i, &b) in buf.iter().take(1000).enumerate() {
            assert_eq!(b, pattern(n - 1000 + i), "tail byte {i}");
        }
        assert!(buf[1000..].iter().all(|&b| b == 0), "overhang must zero-fill");
        // A read entirely past EOF — including past the *member's* end on
        // every device — is all zeros.
        let mut past = vec![0xBBu8; 512];
        backing.read_at((n + 3 * STRIPE as usize) as u64, &mut past);
        assert!(past.iter().all(|&b| b == 0), "far-past-EOF read must zero-fill");
    }
}

#[test]
fn stripe_single_device_is_identity() {
    let bytes: Vec<u8> = (0..FILE_BYTES).map(pattern).collect();
    let member: BackingRef = Arc::new(MemBacking::new(bytes));
    let striped = StripedBacking::new(vec![member], STRIPE);
    // One member collapses to the unstriped degenerate spec: no translation.
    assert_eq!(striped.spec(), StripeSpec::single());
    assert_eq!(striped.len(), FILE_BYTES as u64);
    for (off, len) in [(0usize, 512usize), (4095, 2), (700, 100), (0, FILE_BYTES)] {
        assert_pattern(&striped, off, len, "devices=1 identity");
    }
}

// ---------------------------------------------------------------------------
// meta.toml handshake negative paths (dataset geometry + packed layout)
// ---------------------------------------------------------------------------

/// Every `meta.toml` contract violation must be refused at load time with a
/// message naming the expected *and* the actual value — on both backends,
/// since `--backend os` is exactly where a stale or mismatched on-disk
/// dataset is most likely.
mod meta_handshake {
    use gnndrive::config::{Machine, MachineConfig};
    use gnndrive::graph::{Dataset, DatasetSpec};
    use gnndrive::layout::{pack_dataset, PackedLayout};
    use gnndrive::sample::ScheduleSpec;
    use gnndrive::sim::Clock;
    use gnndrive::storage::BackendKind;
    use std::path::{Path, PathBuf};

    // Uring rides along: `Machine::new` probe-falls-back to the pread stack
    // on kernels without io_uring, and the meta.toml handshake is
    // engine-independent, so this column never needs to skip.
    const KINDS: [BackendKind; 3] = [BackendKind::Sim, BackendKind::Os, BackendKind::Uring];

    fn machine(kind: BackendKind, devices: usize, stripe: u64) -> Machine {
        let mut cfg = MachineConfig::paper().with_backend(kind).with_host_mem(1 << 30);
        if devices > 1 {
            cfg = cfg.with_devices(devices).with_stripe_bytes(stripe);
        }
        Machine::new(cfg, Clock::new(0.05))
    }

    /// Fresh dataset directory per call (tests run concurrently).
    fn fresh_dir(stem: &str) -> PathBuf {
        use std::sync::atomic::{AtomicU32, Ordering};
        static NEXT: AtomicU32 = AtomicU32::new(0);
        let dir = std::env::temp_dir().join(format!(
            "gnndrive_handshake_{stem}_{}_{}",
            std::process::id(),
            NEXT.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_unit_test(dir: &Path, devices: usize) {
        let spec = DatasetSpec::by_name("unit-test").unwrap();
        if devices > 1 {
            Dataset::write_dir_striped(&spec, dir, devices, super::STRIPE).unwrap();
        } else {
            Dataset::write_dir(&spec, dir).unwrap();
        }
    }

    fn sched(seed: u64) -> ScheduleSpec {
        ScheduleSpec { seed, batch_size: 64, fanouts: vec![4, 4], batches_per_epoch: Some(3) }
    }

    fn kind_name(kind: BackendKind) -> &'static str {
        kind.label()
    }

    #[test]
    fn missing_meta_is_refused() {
        for kind in KINDS {
            let name = kind_name(kind);
            let dir = fresh_dir("no_meta");
            let m = machine(kind, 1, 0);
            assert!(Dataset::load_dir(&dir, &m).is_err(), "{name}: dataset load must fail");
            assert!(PackedLayout::load_dir(&dir, &m).is_err(), "{name}: layout load must fail");
        }
    }

    #[test]
    fn corrupt_meta_is_refused() {
        for kind in KINDS {
            let name = kind_name(kind);
            let dir = fresh_dir("bad_meta");
            // Valid dataset files, then clobber the metadata with non-TOML.
            write_unit_test(&dir, 1);
            std::fs::write(dir.join("meta.toml"), "nodes = [unterminated\ngarbage").unwrap();
            let m = machine(kind, 1, 0);
            let err = Dataset::load_dir(&dir, &m).unwrap_err().to_string();
            assert!(err.contains("line"), "{name}: parse error must locate the line: {err}");
            assert!(PackedLayout::load_dir(&dir, &m).is_err(), "{name}: layout load must fail");
        }
    }

    #[test]
    fn stripe_geometry_mismatch_reports_expected_vs_actual() {
        for kind in KINDS {
            let name = kind_name(kind);
            // Unstriped dataset opened by a 3-device machine.
            let dir = fresh_dir("geom_flat");
            write_unit_test(&dir, 1);
            let m3 = machine(kind, 3, super::STRIPE);
            let err = Dataset::load_dir(&dir, &m3).unwrap_err().to_string();
            assert!(err.contains("stripe geometry mismatch"), "{name}: {err}");
            assert!(err.contains("1 device(s)"), "{name}: expected geometry missing: {err}");
            assert!(err.contains("3 device(s)"), "{name}: actual geometry missing: {err}");

            // Striped dataset opened with the right device count but the
            // wrong chunk size: both byte values must be in the message.
            let dir = fresh_dir("geom_chunk");
            write_unit_test(&dir, 3);
            let m_wrong = machine(kind, 3, 2 * super::STRIPE);
            let err = Dataset::load_dir(&dir, &m_wrong).unwrap_err().to_string();
            assert!(err.contains("stripe geometry mismatch"), "{name}: {err}");
            assert!(
                err.contains(&super::STRIPE.to_string())
                    && err.contains(&(2 * super::STRIPE).to_string()),
                "{name}: both chunk sizes must be reported: {err}"
            );
        }
    }

    #[test]
    fn packed_layout_requires_a_pack_and_matching_geometry() {
        // Pack once under the sim machine (the pack files are plain files —
        // both backends read the same bytes).
        let dir = fresh_dir("packed");
        write_unit_test(&dir, 1);
        let sim = machine(BackendKind::Sim, 1, 0);
        let ds = Dataset::load_dir(&dir, &sim).unwrap();
        pack_dataset(&sim, &ds, &dir, &sched(17), 1, 2).unwrap();

        for kind in KINDS {
            let name = kind_name(kind);
            // An unpacked dataset dir is not a packed layout.
            let plain = fresh_dir("unpacked");
            write_unit_test(&plain, 1);
            let m = machine(kind, 1, 0);
            let err = PackedLayout::load_dir(&plain, &m).unwrap_err().to_string();
            assert!(err.contains("pack"), "{name}: must point at `gnndrive pack`: {err}");

            // A pack written unstriped refuses a striped machine.
            let m3 = machine(kind, 3, super::STRIPE);
            let err = PackedLayout::load_dir(&dir, &m3).unwrap_err().to_string();
            assert!(err.contains("stripe geometry mismatch"), "{name}: {err}");
            assert!(
                err.contains("1 device(s)") && err.contains("3 device(s)"),
                "{name}: expected vs actual geometry missing: {err}"
            );
        }
    }

    #[test]
    fn pack_sampler_seed_mismatch_reports_expected_vs_actual() {
        let dir = fresh_dir("seed");
        write_unit_test(&dir, 1);
        let sim = machine(BackendKind::Sim, 1, 0);
        let ds = Dataset::load_dir(&dir, &sim).unwrap();
        pack_dataset(&sim, &ds, &dir, &sched(17), 1, 2).unwrap();

        for kind in KINDS {
            let name = kind_name(kind);
            let m = machine(kind, 1, 0);
            let layout = PackedLayout::load_dir(&dir, &m).unwrap();
            // The matching schedule is accepted (a tighter batch cap is not
            // a mismatch: the capped plan is a prefix of the packed one).
            layout.verify_schedule(&sched(17)).unwrap();
            let mut capped = sched(17);
            capped.batches_per_epoch = Some(2);
            layout.verify_schedule(&capped).unwrap();
            // A different sampler seed is refused with both values named.
            let err = layout.verify_schedule(&sched(18)).unwrap_err().to_string();
            assert!(err.contains("pack sampler seed"), "{name}: {err}");
            assert!(
                err.contains("17") && err.contains("18"),
                "{name}: expected vs actual seed missing: {err}"
            );
        }
    }
}
