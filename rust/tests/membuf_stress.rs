//! Concurrency stress for the sharded feature buffer: ≥8 threads hammer
//! begin_batch / publish / wait_plan / gather / release on a small,
//! high-steal buffer with overlapping node sets, checking data integrity on
//! every gather and the full structural invariants at quiesce points.
//! Refcount underflow panics inside `release` (the buffer asserts) would
//! fail the test via the panicking thread's join.
//!
//! Since the lock-free standby path landed this also covers: release by
//! alias racing lock-free clock claims (`eviction_churn_...`), and a
//! single-threaded determinism check that the alias and node release paths
//! are observationally identical.

use gnndrive::config::{Machine, MachineConfig};
use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec};
use gnndrive::membuf::{FeatureBuffer, StagingBuffer};
use gnndrive::sim::Clock;
use gnndrive::storage::{DeviceMemory, IoBackend as _};
use gnndrive::util::rng::Pcg;
use std::sync::{Arc, Barrier};

const THREADS: usize = 8;
const BATCH: usize = 24;
const ITERS: u64 = 200;
const QUIESCE_EVERY: u64 = 50;
const DIM: usize = 4;
/// Small enough for heavy stealing, large enough that total live references
/// (THREADS × BATCH = 192) plus in-transit stolen slots always fit — the
/// engine's sizing rule, so blocking allocations terminate.
const SLOTS: usize = 256;
/// Node universe ~8× the slot count: heavy steal + cross-thread sharing.
const ID_SPACE: u32 = 2000;

fn batch_for(thread: usize, iter: u64) -> Vec<u32> {
    let mut rng = Pcg::with_stream(0x57E55 + thread as u64, iter);
    let mut ids: Vec<u32> = (0..BATCH).map(|_| rng.below(ID_SPACE)).collect();
    // Unique ids per batch, like the sampler's deduped node list.
    ids.sort_unstable();
    ids.dedup();
    ids
}

#[test]
fn concurrent_begin_publish_release_stress() {
    let dev = DeviceMemory::new(64 << 20);
    let fb = Arc::new(FeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap());
    assert!(fb.shard_count() > 1, "stress should exercise the sharded paths");
    let quiesce = Barrier::new(THREADS);

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let fb = fb.clone();
            let quiesce = &quiesce;
            s.spawn(move || {
                let mut out = vec![0f32; BATCH * DIM];
                for i in 0..ITERS {
                    let batch = batch_for(t, i);
                    let plan = fb.begin_batch(&batch);
                    for &(node, slot) in &plan.to_load {
                        let row: Vec<f32> =
                            (0..DIM).map(|j| (node * 10 + j as u32) as f32).collect();
                        fb.publish(node, slot, &row);
                    }
                    // Rows planned by peers: wait on the pre-resolved
                    // tickets (we hold references, so they cannot be
                    // stolen out from under us).
                    fb.wait_plan(&plan);
                    fb.gather(&plan.aliases, &mut out[..batch.len() * DIM]);
                    for (k, &node) in batch.iter().enumerate() {
                        assert_eq!(
                            out[k * DIM],
                            (node * 10) as f32,
                            "thread {t} iter {i}: node {node} row corrupted"
                        );
                        assert_eq!(
                            out[k * DIM + DIM - 1],
                            (node * 10 + DIM as u32 - 1) as f32,
                            "thread {t} iter {i}: node {node} row tail corrupted"
                        );
                    }
                    fb.release(&batch);
                    // Quiesce: everyone between release and next begin, one
                    // thread validates the cross-shard invariants.
                    if (i + 1) % QUIESCE_EVERY == 0 {
                        quiesce.wait();
                        if t == 0 {
                            fb.check_invariants().unwrap_or_else(|e| {
                                panic!("invariants broken at iter {i}: {e}")
                            });
                            // All batches released → zero refs everywhere.
                            assert_eq!(
                                fb.standby_len(),
                                SLOTS,
                                "refcount leak at quiesce (iter {i})"
                            );
                        }
                        quiesce.wait();
                    }
                }
            });
        }
    });

    fb.check_invariants().unwrap();
    assert_eq!(fb.standby_len(), SLOTS, "all slots zero-ref after join");
    let (hits, _shared, steals, loads) = fb.stats();
    assert!(loads > 0, "stress never loaded anything");
    assert!(steals > 0, "a {SLOTS}-slot buffer over {ID_SPACE} ids must steal");
    assert!(hits > 0, "overlapping batches should produce hits");
}

#[test]
fn concurrent_extractors_agree_on_aliases_under_steal_pressure() {
    // All threads extract the same node sets concurrently; every shared node
    // must resolve to one slot (single load) per round, like the paper's
    // shared-extraction guarantee — but under a buffer small enough that
    // earlier rounds' tenants get stolen.
    let dev = DeviceMemory::new(64 << 20);
    let fb = Arc::new(FeatureBuffer::in_device(&dev, SLOTS, DIM).unwrap());
    for round in 0..20u64 {
        let mut rng = Pcg::with_stream(0xA11A5, round);
        let set: Vec<u32> = {
            let mut ids: Vec<u32> =
                (0..48).map(|_| rng.below(ID_SPACE)).collect();
            ids.sort_unstable();
            ids.dedup();
            ids
        };
        let loads_before = fb.stats().3;
        let aliases: Vec<Vec<i32>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..THREADS)
                .map(|_| {
                    let fb = fb.clone();
                    let set = set.clone();
                    s.spawn(move || {
                        let plan = fb.begin_batch(&set);
                        for &(node, slot) in &plan.to_load {
                            fb.publish(node, slot, &[node as f32; DIM]);
                        }
                        fb.wait_plan(&plan);
                        plan.aliases
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for a in &aliases[1..] {
            assert_eq!(a, &aliases[0], "round {round}: threads disagree on aliases");
        }
        // The sharing guarantee: a node is loaded at most once per round no
        // matter how many extractors plan it concurrently (residents from
        // earlier rounds load zero times).
        let new_loads = fb.stats().3 - loads_before;
        assert!(
            new_loads as usize <= set.len(),
            "round {round}: {new_loads} loads for {} distinct nodes",
            set.len()
        );
        // Every alias resolves to the right row.
        let mut out = vec![0f32; set.len() * DIM];
        fb.gather(&aliases[0], &mut out);
        for (k, &node) in set.iter().enumerate() {
            assert_eq!(out[k * DIM], node as f32, "round {round}: node {node} row");
        }
        // Each thread's batch took one reference on every node.
        for _ in 0..THREADS {
            fb.release(&set);
        }
        fb.check_invariants().unwrap();
        assert_eq!(fb.standby_len(), SLOTS, "round {round}: refs leaked");
    }
}

#[test]
fn eviction_churn_with_alias_release_under_tiny_buffer() {
    // Eviction-churn stress for the lock-free standby path: the buffer is
    // far smaller than the working set (every batch triggers clock claims),
    // references are dropped through `release_aliases` (the engine's path —
    // no shard lock anywhere between publish and the next begin), and the
    // full structural invariants are validated at quiesce points. The
    // gather check catches any claim that stole a slot still referenced.
    const CHURN_SLOTS: usize = 256;
    const CHURN_IDS: u32 = 20_000; // ~80× the slot count: constant eviction
    let dev = DeviceMemory::new(64 << 20);
    let fb = Arc::new(FeatureBuffer::in_device(&dev, CHURN_SLOTS, DIM).unwrap());
    let quiesce = Barrier::new(THREADS);

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let fb = fb.clone();
            let quiesce = &quiesce;
            s.spawn(move || {
                let mut out = vec![0f32; BATCH * DIM];
                for i in 0..ITERS {
                    let mut rng = Pcg::with_stream(0xC0FFEE + t as u64, i);
                    let mut batch: Vec<u32> =
                        (0..BATCH).map(|_| rng.below(CHURN_IDS)).collect();
                    batch.sort_unstable();
                    batch.dedup();
                    let plan = fb.begin_batch(&batch);
                    for &(node, slot) in &plan.to_load {
                        let row: Vec<f32> =
                            (0..DIM).map(|j| (node * 10 + j as u32) as f32).collect();
                        fb.publish(node, slot, &row);
                    }
                    fb.wait_plan(&plan);
                    fb.gather(&plan.aliases, &mut out[..batch.len() * DIM]);
                    for (k, &node) in batch.iter().enumerate() {
                        assert_eq!(
                            out[k * DIM],
                            (node * 10) as f32,
                            "thread {t} iter {i}: node {node} row corrupted under churn"
                        );
                    }
                    fb.release_aliases(&plan.aliases);
                    if (i + 1) % QUIESCE_EVERY == 0 {
                        quiesce.wait();
                        if t == 0 {
                            fb.check_invariants().unwrap_or_else(|e| {
                                panic!("invariants broken at iter {i}: {e}")
                            });
                            assert_eq!(
                                fb.standby_len(),
                                CHURN_SLOTS,
                                "refcount leak at quiesce (iter {i})"
                            );
                        }
                        quiesce.wait();
                    }
                }
            });
        }
    });

    fb.check_invariants().unwrap();
    assert_eq!(fb.standby_len(), CHURN_SLOTS, "all slots zero-ref after join");
    let (_, _, steals, loads) = fb.stats();
    assert!(loads > 0);
    assert!(
        steals > loads / 4,
        "a {CHURN_SLOTS}-slot buffer over {CHURN_IDS} ids must churn (steals {steals}, loads {loads})"
    );
}

#[test]
fn multi_tenant_serving_workers_share_one_buffer_with_balanced_io() {
    // The serving frontend's tenancy contract at the membuf layer: N
    // serving workers plus one trainer hammer ONE feature buffer through
    // real extractors (async direct I/O, full submit→publish→release
    // lifecycle) with overlapping skewed node sets. After shutdown there
    // must be zero leaked references or slots, and the backend's charged
    // I/O must balance exactly against the buffer's load count — every
    // loaded row charged exactly once (shared in-flight extractions and
    // cross-tenant hits charge nothing), nothing in flight left behind.
    const SERVERS: usize = 4; // + 1 trainer below
    const SLOTS: usize = 256;
    const ROUNDS: u64 = 60;
    const BATCH: usize = 24;

    let machine = Machine::new(MachineConfig::paper(), Clock::new(0.05));
    let ds = Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap();
    let dim = ds.spec.dim; // 16 → 64 B rows, 8 per 512 B sector
    let row_bytes = ds.features.row_bytes() as usize;
    let fb = Arc::new(FeatureBuffer::in_host(&machine.host, SLOTS, dim).unwrap());
    // Hot head shared by every tenant: heavy cross-thread reuse + stealing.
    let hot_ids: u32 = 600;

    machine.backend.reset_io_stats();
    let dio0 = machine.backend.direct_stats().snapshot();

    std::thread::scope(|s| {
        for t in 0..SERVERS + 1 {
            let fb = fb.clone();
            let machine = &machine;
            let ds = &ds;
            s.spawn(move || {
                // Per-row requests (coalescing off) so the charge balance
                // below is exact: one charged request per loaded row.
                let staging =
                    StagingBuffer::new(&machine.host, 64, row_bytes).unwrap();
                let ex = Extractor::with_options(
                    machine.backend.clone(),
                    32,
                    staging,
                    fb.clone(),
                    ds.features.clone(),
                    ExtractTarget::Host,
                    ExtractOptions {
                        coalesce: CoalesceConfig::disabled(),
                        ..Default::default()
                    },
                );
                let mut out = vec![0f32; BATCH * dim];
                let mut want = vec![0u8; row_bytes];
                for i in 0..ROUNDS {
                    let mut rng = Pcg::with_stream(0x7E4A17 + t as u64, i);
                    let mut batch: Vec<u32> = (0..BATCH)
                        .map(|_| {
                            if t == SERVERS {
                                // The "trainer" walks a colder range too.
                                rng.below(ds.spec.nodes)
                            } else {
                                rng.below(hot_ids)
                            }
                        })
                        .collect();
                    batch.sort_unstable();
                    batch.dedup();
                    let aliases = ex.extract(&batch);
                    fb.gather(&aliases, &mut out[..batch.len() * dim]);
                    for (k, &node) in batch.iter().enumerate() {
                        ds.feature_gen.fill_row(node as u64, &mut want);
                        let exp = gnndrive::graph::FeatureGen::decode_row(&want);
                        assert_eq!(
                            &out[k * dim..k * dim + dim],
                            &exp[..],
                            "tenant {t} round {i}: node {node} row corrupted"
                        );
                    }
                    fb.release_aliases(&aliases);
                }
            });
        }
    });

    // Zero leaked references or slots.
    fb.check_invariants().unwrap();
    assert_eq!(fb.standby_len(), SLOTS, "slot references leaked after shutdown");
    let (hits, _shared, steals, loads) = fb.stats();
    assert!(hits > 0, "hot head must produce cross-tenant hits");
    assert!(steals > 0, "cold trainer traffic must churn the buffer");
    assert!(loads > 0);

    // Balanced I/O accounting: per-row direct extraction charges exactly
    // one request per loaded row, each one sector (64 B rows never straddle
    // 512 B sectors), and useful bytes are exactly the row bytes. Nothing
    // else touched the device.
    let reads = machine
        .backend
        .io_counters()
        .reads
        .load(std::sync::atomic::Ordering::Relaxed);
    let read_bytes = machine
        .backend
        .io_counters()
        .read_bytes
        .load(std::sync::atomic::Ordering::Relaxed);
    let (useful, aligned) = {
        let (u, a) = machine.backend.direct_stats().snapshot();
        (u - dio0.0, a - dio0.1)
    };
    assert_eq!(reads, loads, "charged requests must balance loaded rows");
    assert_eq!(read_bytes, loads * 512, "one sector charged per loaded row");
    assert_eq!(useful, loads * row_bytes as u64, "useful bytes = row bytes");
    assert_eq!(aligned, loads * 512, "aligned bytes = one sector per row");
}

#[test]
fn release_by_alias_and_by_node_are_observationally_identical() {
    // Determinism: the same single-threaded schedule driven through
    // `release_aliases` and through `release` must produce identical alias
    // assignments, identical (hits, shared, steals, loads), and identical
    // standby counts at every step — release-by-alias is a pure fast path,
    // not a semantic change.
    const DET_SLOTS: usize = 96;
    const DET_IDS: u32 = 400;
    let dev = DeviceMemory::new(64 << 20);
    let by_alias = FeatureBuffer::in_device(&dev, DET_SLOTS, DIM).unwrap();
    let by_node = FeatureBuffer::in_device(&dev, DET_SLOTS, DIM).unwrap();
    for i in 0..400u64 {
        let mut rng = Pcg::with_stream(0xDE7, i);
        let mut batch: Vec<u32> = (0..24).map(|_| rng.below(DET_IDS)).collect();
        batch.sort_unstable();
        batch.dedup();
        let pa = by_alias.begin_batch(&batch);
        let pn = by_node.begin_batch(&batch);
        assert_eq!(pa.aliases, pn.aliases, "iter {i}: alias divergence");
        assert_eq!(pa.to_load, pn.to_load, "iter {i}: load-plan divergence");
        for &(node, slot) in &pa.to_load {
            by_alias.publish(node, slot, &[node as f32; DIM]);
            by_node.publish(node, slot, &[node as f32; DIM]);
        }
        by_alias.release_aliases(&pa.aliases);
        by_node.release(&batch);
        assert_eq!(by_alias.stats(), by_node.stats(), "iter {i}: stats divergence");
        assert_eq!(
            by_alias.standby_len(),
            by_node.standby_len(),
            "iter {i}: standby divergence"
        );
    }
    by_alias.check_invariants().unwrap();
    by_node.check_invariants().unwrap();
    assert_eq!(by_alias.standby_len(), DET_SLOTS);
    let (_, _, steals, _) = by_alias.stats();
    assert!(steals > 0, "the schedule must exercise clock claims");
}
