//! Chaos suite: injected I/O faults end to end. Storms are *seeded* — every
//! verdict is a pure function of `(plan.seed, offset, cumulative try#)` — so
//! tests either self-select seeds with known fault/recovery shapes (via
//! `FaultPlan::transient_verdict`) or assert properties that hold for any
//! draw sequence (typed errors, exact retry accounting, zero leaked refs,
//! deterministic replay). Covers both I/O backends, the engine-core panic
//! containment + poison path, the training pipeline's `--on-io-error`
//! policies, and the serving frontend's per-request error responses.

use gnndrive::baselines::sim_trainer;
use gnndrive::config::{FaultProfile, Machine, MachineConfig, OnIoError, TrainConfig};
use gnndrive::extract::{CoalesceConfig, ExtractOptions, ExtractTarget, Extractor};
use gnndrive::graph::{Dataset, DatasetSpec, FeatureGen, FeatureTable};
use gnndrive::membuf::{FeatureBuffer, SlotRef, StagingArena, StagingBuffer};
use gnndrive::pipeline::{GnnDrive, Variant};
use gnndrive::runtime::simcompute::ModelKind;
use gnndrive::serve::{BatchSpec, ServeConfig, ServeEngine};
use gnndrive::sim::Clock;
use gnndrive::storage::{
    AsyncIoEngine, BackendKind, DataKind, DirectIoStats, FaultInjectBackend, FaultPlan,
    FileBacking, FileId, HostMemory, IoBackend, IoError, IoMode, MemBacking, OsFileBackend,
    PageCache, RetryPolicy, SimFile, Sqe, SsdConfig, SsdCounters, SsdSim, Storage, Uring,
};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 16;
const NODES: u64 = 200;
const ROW: u64 = (DIM * 4) as u64;

/// Unique tempdir path per call (tests run concurrently in one binary).
fn unique_path(stem: &str) -> std::path::PathBuf {
    use std::sync::atomic::AtomicU32;
    static NEXT: AtomicU32 = AtomicU32::new(0);
    let dir = std::env::temp_dir().join("gnndrive_faults");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!(
        "{stem}_{}_{}.bin",
        std::process::id(),
        NEXT.fetch_add(1, Ordering::Relaxed)
    ))
}

// ---------------------------------------------------------------------------
// Extractor-level rig: any backend wrapped in a fault plan
// ---------------------------------------------------------------------------

struct Rig {
    io: Arc<dyn IoBackend>,
    fb: Arc<FeatureBuffer>,
    ex: Extractor,
    gen: FeatureGen,
}

/// Extraction rig over `kind` wrapped in `plan`/`policy`. Coalescing is
/// disabled so request offsets are exactly `node × ROW` — the property the
/// seed-self-selection helpers rely on.
fn rig(kind: BackendKind, plan: FaultPlan, policy: RetryPolicy) -> Rig {
    let labels = Arc::new((0..NODES as usize).map(|v| (v % 4) as u16).collect::<Vec<u16>>());
    let gen = FeatureGen::new(0xC0FFEE, DIM, 4, 0.3, labels);
    let (inner, features): (Arc<dyn IoBackend>, FeatureTable) = match kind {
        BackendKind::Sim => {
            let clock = Clock::new(0.05);
            let ssd = SsdSim::new(SsdConfig::pm883(), clock);
            let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
            (
                Arc::new(Storage::new(ssd, cache)),
                FeatureTable::procedural(FileId::new(21, DataKind::Features), NODES, gen.clone()),
            )
        }
        BackendKind::Os => {
            let path = unique_path("features");
            FeatureTable::write_file(&path, NODES, &gen).unwrap();
            (
                Arc::new(OsFileBackend::new(512)),
                FeatureTable::from_backing(
                    FileId::new(21, DataKind::Features),
                    NODES,
                    DIM,
                    Arc::new(FileBacking::open(&path).unwrap()),
                ),
            )
        }
    };
    let io: Arc<dyn IoBackend> =
        Arc::new(FaultInjectBackend::new(inner, kind, plan, policy, Clock::new(0.05)));
    let host = HostMemory::new(1 << 20);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, DIM).unwrap());
    let staging = StagingBuffer::new(&host, 16, DIM * 4).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        16,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions { coalesce: CoalesceConfig::disabled(), ..Default::default() },
    );
    Rig { io, fb, ex, gen }
}

fn verify_rows(rig: &Rig, nodes: &[u32], aliases: &[i32]) {
    let mut out = vec![0f32; DIM];
    let mut want = vec![0u8; DIM * 4];
    for (i, &v) in nodes.iter().enumerate() {
        rig.fb.gather(&aliases[i..i + 1], &mut out);
        rig.gen.fill_row(v as u64, &mut want);
        assert_eq!(out, FeatureGen::decode_row(&want), "node {v}");
    }
}

fn fault_delta(io: &dyn IoBackend, base: (u64, u64, u64)) -> (u64, u64, u64) {
    let (r, f, d) = io.direct_stats().fault_snapshot();
    (r - base.0, f - base.1, d - base.2)
}

/// First seed whose transient storm at `rate` (a) faults at least one
/// offset's first try and (b) never faults any offset four tries in a row —
/// i.e. a storm the default 3-retry policy deterministically rides out.
fn pick_recoverable_seed(rate: f64, offsets: &[u64]) -> u64 {
    'seed: for seed in 0..20_000u64 {
        let plan = FaultPlan::transient(seed, rate);
        let mut any_first = false;
        for &off in offsets {
            if (0..4).all(|t| plan.transient_verdict(off, t)) {
                continue 'seed;
            }
            any_first |= plan.transient_verdict(off, 0);
        }
        if any_first {
            return seed;
        }
    }
    panic!("no recoverable seed in the search space");
}

#[test]
fn transient_storm_recovers_with_correct_bytes_on_both_backends() {
    let nodes: Vec<u32> = (30..90).collect();
    let offsets: Vec<u64> = nodes.iter().map(|&v| v as u64 * ROW).collect();
    let seed = pick_recoverable_seed(0.3, &offsets);
    for kind in [BackendKind::Sim, BackendKind::Os] {
        let mut plan = FaultPlan::transient(seed, 0.3);
        // Exercise the stall path too: 50 µs hiccups change timing only.
        plan.stall_rate = 0.2;
        plan.stall_us = 50;
        let rig = rig(kind, plan, RetryPolicy::default());
        let base = rig.io.direct_stats().fault_snapshot();
        let aliases =
            rig.ex.try_extract(&nodes).expect("storm within the retry budget must recover");
        verify_rows(&rig, &nodes, &aliases);
        let (retries, failures, _) = fault_delta(rig.io.as_ref(), base);
        assert!(retries > 0, "{kind:?}: the selected seed faults at least one first try");
        assert_eq!(failures, 0, "{kind:?}: every fault must recover within the policy");
        rig.fb.release_aliases(&aliases);
        rig.fb.check_invariants().unwrap();
    }
}

#[test]
fn bad_range_rows_fail_typed_and_extractor_stays_usable() {
    let plan = FaultPlan { bad_ranges: vec![(0u64, 32 * ROW)], ..FaultPlan::default() };
    let rig = rig(BackendKind::Sim, plan, RetryPolicy::default());
    let base = rig.io.direct_stats().fault_snapshot();
    let nodes: Vec<u32> = (0..40).collect();
    let err = rig.ex.try_extract(&nodes).expect_err("rows in a bad range cannot extract");
    assert!(matches!(err.error, IoError::BadRange { .. }), "got {:?}", err.error);
    let mut failed = err.failed_nodes.clone();
    failed.sort_unstable();
    assert_eq!(failed, (0..32).collect::<Vec<u32>>(), "exactly the bad-range rows fail");
    assert_eq!(err.aliases.len(), nodes.len(), "alias list stays full-length for release");
    let (retries, failures, _) = fault_delta(rig.io.as_ref(), base);
    assert_eq!(retries, 0, "permanent errors must not be retried");
    assert_eq!(failures, 32);
    rig.fb.release_aliases(&err.aliases);

    // The same extractor keeps serving rows outside the bad range.
    let good: Vec<u32> = (100..120).collect();
    let aliases = rig.ex.try_extract(&good).expect("rows outside the bad range still extract");
    verify_rows(&rig, &good, &aliases);
    rig.fb.release_aliases(&aliases);
    rig.fb.check_invariants().unwrap();
}

#[test]
fn short_read_retries_are_counted_exactly_then_fail_typed() {
    // Rate 1.0 → every try short-reads: each request burns the full retry
    // budget, so the counters are exact, not probabilistic.
    let plan = FaultPlan { short_rate: 1.0, ..FaultPlan::default() };
    let policy = RetryPolicy { max_retries: 2, ..RetryPolicy::default() };
    let rig = rig(BackendKind::Sim, plan, policy);
    let base = rig.io.direct_stats().fault_snapshot();
    let nodes: Vec<u32> = (10..26).collect();
    let err = rig.ex.try_extract(&nodes).expect_err("rate-1.0 short reads exhaust the policy");
    assert!(matches!(err.error, IoError::ShortRead { .. }), "got {:?}", err.error);
    assert_eq!(err.failed_nodes.len(), nodes.len());
    let (retries, failures, _) = fault_delta(rig.io.as_ref(), base);
    assert_eq!(retries, 2 * nodes.len() as u64, "two re-attempts per request");
    assert_eq!(failures, nodes.len() as u64, "one failure per exhausted request");
    rig.fb.release_aliases(&err.aliases);
    rig.fb.check_invariants().unwrap();
}

#[test]
fn deadline_gives_up_with_typed_error_before_retrying() {
    let plan = FaultPlan { short_rate: 1.0, ..FaultPlan::default() };
    let policy =
        RetryPolicy { max_retries: 10, deadline_us: Some(0), ..RetryPolicy::default() };
    let rig = rig(BackendKind::Sim, plan, policy);
    let base = rig.io.direct_stats().fault_snapshot();
    let nodes: Vec<u32> = (0..8).collect();
    let err = rig.ex.try_extract(&nodes).expect_err("a zero deadline fails every request");
    assert!(matches!(err.error, IoError::Deadline), "got {:?}", err.error);
    let (retries, failures, _) = fault_delta(rig.io.as_ref(), base);
    assert_eq!(retries, 0, "an expired deadline must not re-attempt");
    assert_eq!(failures, nodes.len() as u64);
    rig.fb.release_aliases(&err.aliases);
    rig.fb.check_invariants().unwrap();
}

#[test]
fn fault_storms_replay_deterministically() {
    let run = || {
        let plan = FaultPlan::transient(0x00D5_0001, 0.45);
        let policy = RetryPolicy { max_retries: 1, ..RetryPolicy::default() };
        let rig = rig(BackendKind::Sim, plan, policy);
        let nodes: Vec<u32> = (0..120).collect();
        let failed = match rig.ex.try_extract(&nodes) {
            Ok(aliases) => {
                rig.fb.release_aliases(&aliases);
                Vec::new()
            }
            Err(e) => {
                let mut f = e.failed_nodes.clone();
                f.sort_unstable();
                rig.fb.release_aliases(&e.aliases);
                f
            }
        };
        rig.fb.check_invariants().unwrap();
        let (r, f, _) = rig.io.direct_stats().fault_snapshot();
        (failed, r, f)
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same plan + same request sequence must replay identically");
    assert!(a.1 > 0, "a 45% storm over 120 rows must produce retries");
}

#[test]
fn batch_level_re_extract_continues_the_draw_sequence() {
    // With no engine retries, try #0 of each offset is drawn by the first
    // extract and try #1 by the re-extract. A seed where some offset faults
    // try #0 but none fault both tries proves the cumulative counter: a
    // (offset, attempt)-keyed plan would replay try #0 and fail forever.
    let nodes: Vec<u32> = (40..56).collect();
    let offsets: Vec<u64> = nodes.iter().map(|&v| v as u64 * ROW).collect();
    let seed = (0..20_000u64)
        .find(|&s| {
            let plan = FaultPlan::transient(s, 0.08);
            offsets.iter().all(|&o| !(plan.transient_verdict(o, 0) && plan.transient_verdict(o, 1)))
                && offsets.iter().any(|&o| plan.transient_verdict(o, 0))
        })
        .expect("no suitable seed in the search space");
    let rig = rig(BackendKind::Sim, FaultPlan::transient(seed, 0.08), RetryPolicy::none());
    let err =
        rig.ex.try_extract(&nodes).expect_err("first-try faults with no retries fail the batch");
    assert!(matches!(err.error, IoError::Transient));
    // The degradation protocol: release the batch refs, evict the failed
    // rows' placeholders, re-extract (what `--on-io-error retry` does).
    rig.fb.release_aliases(&err.aliases);
    rig.fb.evict_if_idle(&err.failed_nodes);
    let aliases = rig.ex.try_extract(&nodes).expect("the re-extract must see fresh draws");
    verify_rows(&rig, &nodes, &aliases);
    rig.fb.release_aliases(&aliases);
    rig.fb.check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Striped arrays: logical-offset fault keying + --fault-device targeting
// ---------------------------------------------------------------------------

/// Extraction rig over a striped sim array wrapped in `plan`/`policy` —
/// the striped counterpart of [`rig`] (coalescing disabled, so request
/// offsets are exactly `node × ROW`).
fn striped_rig(devices: usize, stripe_bytes: u64, plan: FaultPlan, policy: RetryPolicy) -> Rig {
    let labels = Arc::new((0..NODES as usize).map(|v| (v % 4) as u16).collect::<Vec<u16>>());
    let gen = FeatureGen::new(0xC0FFEE, DIM, 4, 0.3, labels);
    let clock = Clock::new(0.05);
    let ssds = (0..devices).map(|_| SsdSim::new(SsdConfig::pm883(), clock.clone())).collect();
    let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
    let inner: Arc<dyn IoBackend> = Arc::new(Storage::new_striped(ssds, cache, stripe_bytes));
    let features =
        FeatureTable::procedural(FileId::new(21, DataKind::Features), NODES, gen.clone());
    let io: Arc<dyn IoBackend> =
        Arc::new(FaultInjectBackend::new(inner, BackendKind::Sim, plan, policy, Clock::new(0.05)));
    let host = HostMemory::new(1 << 20);
    let fb = Arc::new(FeatureBuffer::in_host(&host, 256, DIM).unwrap());
    let staging = StagingBuffer::new(&host, 16, DIM * 4).unwrap();
    let ex = Extractor::with_options(
        io.clone(),
        16,
        staging,
        fb.clone(),
        features,
        ExtractTarget::Host,
        ExtractOptions { coalesce: CoalesceConfig::disabled(), ..Default::default() },
    );
    Rig { io, fb, ex, gen }
}

#[test]
fn fault_storms_replay_deterministically_across_striped_array() {
    // The plan draws on logical `(offset, try#)` — never on device-local
    // offsets — so the same storm must produce the *same* failed set on a
    // flat backend, on a striped one, and on a striped re-run, even though
    // striping reorders submission across per-device queues.
    let run = |devices: usize| {
        let plan = FaultPlan::transient(0x00D5_0001, 0.45);
        let policy = RetryPolicy { max_retries: 1, ..RetryPolicy::default() };
        let rig = if devices == 1 {
            rig(BackendKind::Sim, plan, policy)
        } else {
            striped_rig(devices, 4096, plan, policy)
        };
        let nodes: Vec<u32> = (0..120).collect();
        let failed = match rig.ex.try_extract(&nodes) {
            Ok(aliases) => {
                rig.fb.release_aliases(&aliases);
                Vec::new()
            }
            Err(e) => {
                let mut f = e.failed_nodes.clone();
                f.sort_unstable();
                rig.fb.release_aliases(&e.aliases);
                f
            }
        };
        rig.fb.check_invariants().unwrap();
        let (r, f, _) = rig.io.direct_stats().fault_snapshot();
        (failed, r, f)
    };
    let flat = run(1);
    let striped_a = run(3);
    let striped_b = run(3);
    assert_eq!(striped_a, striped_b, "striped replays must be deterministic");
    assert_eq!(flat, striped_a, "striping must not change which logical offsets fault");
    assert!(flat.1 > 0, "a 45% storm over 120 rows must produce retries");
}

#[test]
fn fault_device_targets_only_one_stripe_member() {
    // Permanent failure of stripe member 1 only. 64 B rows, 1 KiB chunks on
    // 3 devices: a chunk holds 16 rows, so device 1 owns nodes 16..32 and
    // 64..80 within 0..96 — exactly those must fail, everything else reads.
    let plan = FaultPlan {
        bad_ranges: vec![(0u64, u64::MAX)],
        device: Some(1),
        ..FaultPlan::default()
    };
    let rig = striped_rig(3, 1024, plan, RetryPolicy::default());
    let base = rig.io.direct_stats().fault_snapshot();
    let nodes: Vec<u32> = (0..96).collect();
    let err = rig.ex.try_extract(&nodes).expect_err("device-1 rows cannot extract");
    assert!(matches!(err.error, IoError::BadRange { .. }), "got {:?}", err.error);
    let mut failed = err.failed_nodes.clone();
    failed.sort_unstable();
    let want: Vec<u32> = (16..32).chain(64..80).collect();
    assert_eq!(failed, want, "exactly the targeted device's rows fail");
    let (retries, failures, _) = fault_delta(rig.io.as_ref(), base);
    assert_eq!(retries, 0, "permanent errors must not be retried");
    assert_eq!(failures, want.len() as u64);
    rig.fb.release_aliases(&err.aliases);

    // The surviving members keep serving bytes.
    let good: Vec<u32> = (32..64).collect();
    let aliases = rig.ex.try_extract(&good).expect("devices 0 and 2 are healthy");
    verify_rows(&rig, &good, &aliases);
    rig.fb.release_aliases(&aliases);
    rig.fb.check_invariants().unwrap();
}

#[test]
fn single_device_storm_degrades_gracefully_under_drop_rows() {
    // End to end: stripe member 0 goes permanently bad mid-array; training
    // under `--on-io-error drop-rows` must complete the epoch, dropping only
    // the rows that live on the dead member while the other two keep serving.
    let profile = FaultProfile {
        plan: FaultPlan {
            bad_ranges: vec![(0u64, u64::MAX)],
            device: Some(0),
            ..FaultPlan::default()
        },
        policy: RetryPolicy::default(),
    };
    let machine = Arc::new(Machine::new(
        MachineConfig::paper().with_devices(3).with_stripe_bytes(4096).with_fault(profile),
        Clock::new(0.05),
    ));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    let engine = train_engine(&machine, &ds, quick_cfg(OnIoError::DropRows));
    let stats = engine.try_run_epoch(0).expect("a one-member storm must not kill the epoch");
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.train.steps, 4);
    assert!(stats.dropped_rows > 0, "the dead member's rows must be dropped");
    assert!(stats.io_failures > 0);
    // The healthy members carried the epoch: per-device accounting shows
    // reads landing on more than one device.
    assert_eq!(stats.device_reads.len(), 3, "one read breakdown entry per stripe member");
    let active = stats.device_reads.iter().filter(|&&(r, _)| r > 0).count();
    assert!(active >= 2, "healthy devices must keep serving: {:?}", stats.device_reads);
    // The striped epoch line carries the per-device split and queue depths.
    let line = stats.summary();
    assert!(line.contains("dev["), "striped summary must show the device split: {line}");
    engine.feature_buffer().check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Engine-core panic containment (per-request guard + worker-loss poisoning)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum ChaosMode {
    /// Panic inside the backend read of one offset — contained per request
    /// by `serve_sqe` and classified as `IoError::Internal`.
    PanicOnRead { offset: u64 },
    /// Panic in the worker loop *outside* the per-request guard (the
    /// chunk-charge call) — kills the worker; the poison guard must convert
    /// the hang into typed `EnginePoisoned` completions.
    PanicOnCharge,
}

struct ChaosBackend {
    inner: Arc<dyn IoBackend>,
    mode: ChaosMode,
}

impl IoBackend for ChaosBackend {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn sector(&self) -> usize {
        self.inner.sector()
    }

    fn read_buffered(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        self.inner.read_buffered(file, offset, buf)
    }

    fn read_direct(&self, file: &SimFile, offset: u64, buf: &mut [u8]) {
        self.inner.read_direct(file, offset, buf)
    }

    fn read_direct_segment_nocharge(
        &self,
        file: &SimFile,
        offset: u64,
        useful: usize,
        buf: &mut [u8],
    ) -> usize {
        if matches!(self.mode, ChaosMode::PanicOnRead { offset: bad } if bad == offset) {
            panic!("chaos: injected read panic at offset {offset}");
        }
        self.inner.read_direct_segment_nocharge(file, offset, useful, buf)
    }

    fn charge_multi(&self, ops: u64, bytes: usize) {
        if ops > 0 && matches!(self.mode, ChaosMode::PanicOnCharge) {
            panic!("chaos: injected worker-loop panic");
        }
        self.inner.charge_multi(ops, bytes)
    }

    fn write_buffered(&self, file: &SimFile, offset: u64, len: usize) {
        self.inner.write_buffered(file, offset, len)
    }

    fn write_direct(&self, file: &SimFile, offset: u64, len: usize) {
        self.inner.write_direct(file, offset, len)
    }

    fn charge_read(&self, len: usize) {
        self.inner.charge_read(len)
    }

    fn charge_write(&self, len: usize) {
        self.inner.charge_write(len)
    }

    fn direct_stats(&self) -> &DirectIoStats {
        self.inner.direct_stats()
    }

    fn io_counters(&self) -> &SsdCounters {
        self.inner.io_counters()
    }

    fn reset_io_stats(&self) {
        self.inner.reset_io_stats()
    }

    fn async_engine(self: Arc<Self>, depth: usize) -> Box<dyn AsyncIoEngine> {
        Box::new(Uring::new(self, depth))
    }
}

fn chaos_rig(mode: ChaosMode) -> (Arc<dyn IoBackend>, SimFile) {
    let clock = Clock::new(0.05);
    let ssd = SsdSim::new(SsdConfig::pm883(), clock);
    let cache = Arc::new(PageCache::new(HostMemory::new(1 << 20)));
    let inner: Arc<dyn IoBackend> = Arc::new(Storage::new(ssd, cache));
    let bytes: Vec<u8> = (0..(64usize << 10)).map(|i| (i % 241) as u8).collect();
    let file =
        SimFile::new(FileId::new(33, DataKind::Features), Arc::new(MemBacking::new(bytes)));
    (Arc::new(ChaosBackend { inner, mode }), file)
}

fn chaos_sqes(file: &SimFile, arena: &StagingArena, n: usize, base_row: usize) -> Vec<Sqe> {
    (0..n)
        .map(|i| Sqe {
            file: file.clone(),
            offset: ((base_row + i) * 512) as u64,
            len: 512,
            useful: 512,
            dst: SlotRef::new(arena.clone(), i),
            dst_off: 0,
            user_data: (base_row + i) as u64,
            mode: IoMode::Direct,
        })
        .collect()
}

#[test]
fn backend_panic_becomes_typed_internal_error() {
    let (io, file) = chaos_rig(ChaosMode::PanicOnRead { offset: 2 * 512 });
    let engine = io.clone().async_engine(16);
    const N: usize = 8;
    let arena = StagingArena::new(N, 512);
    engine.submit_batch(chaos_sqes(&file, &arena, N, 0));
    let cqes = engine.wait_cqes(N);
    let (mut ok, mut internal) = (0, 0);
    for c in &cqes {
        match &c.status {
            Ok(_) => ok += 1,
            Err(IoError::Internal) => {
                internal += 1;
                assert_eq!(c.user_data, 2, "the panicking request fails, nothing else");
            }
            Err(e) => panic!("unexpected error {e:?}"),
        }
    }
    assert_eq!((ok, internal), (N - 1, 1));
    assert_eq!(engine.inflight(), 0);
    assert_eq!(engine.pending_harvest(), 0);
    // The engine survives: a fresh batch on clean offsets completes fully.
    engine.submit_batch(chaos_sqes(&file, &arena, N, N));
    assert!(engine.wait_cqes(N).iter().all(|c| c.status.is_ok()));
    assert_eq!(engine.inflight(), 0);
}

#[test]
fn lost_workers_poison_the_engine_instead_of_hanging() {
    let (io, file) = chaos_rig(ChaosMode::PanicOnCharge);
    let engine = io.clone().async_engine(16);
    const N: usize = 8;
    let arena = StagingArena::new(N, 512);
    engine.submit_batch(chaos_sqes(&file, &arena, N, 0));
    // Every worker that serves a chunk dies before publishing its CQEs, so
    // the harvest must come back as synthetic typed errors — the old
    // behavior was an unbounded hang right here.
    let cqes = engine.wait_cqes(N);
    assert_eq!(cqes.len(), N);
    assert!(
        cqes.iter().all(|c| c.status.is_err()),
        "no completion may claim success after worker loss"
    );
    assert!(
        cqes.iter().any(|c| matches!(c.status, Err(IoError::EnginePoisoned))),
        "worker loss must surface as EnginePoisoned"
    );
    // Drain reconciles the counters instead of waiting forever.
    engine.drain();
    assert_eq!(engine.inflight(), 0);
    assert_eq!(engine.pending_harvest(), 0);
}

// ---------------------------------------------------------------------------
// Training pipeline: --on-io-error policies end to end
// ---------------------------------------------------------------------------

fn machine_with(profile: FaultProfile) -> (Arc<Machine>, Arc<Dataset>) {
    let machine =
        Arc::new(Machine::new(MachineConfig::paper().with_fault(profile), Clock::new(0.05)));
    let ds = Arc::new(Dataset::materialize(&DatasetSpec::unit_test(), &machine).unwrap());
    (machine, ds)
}

fn quick_cfg(on_io_error: OnIoError) -> TrainConfig {
    TrainConfig {
        batch_size: 64,
        fanouts: vec![4, 4],
        batches_per_epoch: Some(4),
        samplers: 2,
        extractors: 2,
        io_depth: 32,
        on_io_error,
        ..TrainConfig::default()
    }
}

fn train_engine(machine: &Arc<Machine>, ds: &Arc<Dataset>, cfg: TrainConfig) -> GnnDrive {
    let trainer = sim_trainer(machine, ds, &cfg, ModelKind::GraphSage, Variant::Gpu, 64);
    GnnDrive::new(machine, ds, cfg, Variant::Gpu, trainer).unwrap()
}

#[test]
fn training_storm_completes_with_retries_and_zero_failures() {
    // 5% transient faults against a 6-deep retry budget: the epoch must ride
    // out the storm on engine retries alone (failure would need 7 faulted
    // draws in a row on one offset).
    let profile = FaultProfile {
        plan: FaultPlan::transient(0x0057_0311, 0.05),
        policy: RetryPolicy { max_retries: 6, ..RetryPolicy::default() },
    };
    let (machine, ds) = machine_with(profile);
    let engine = train_engine(&machine, &ds, quick_cfg(OnIoError::Fail));
    let stats = engine.try_run_epoch(0).expect("a 5% storm must ride out on retries");
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.train.steps, 4);
    assert!(stats.io_retries > 0, "the storm must surface in the epoch counters");
    assert_eq!(stats.io_failures, 0, "no request may exhaust a 6-deep retry budget");
    assert_eq!(stats.dropped_rows, 0);
    engine.feature_buffer().check_invariants().unwrap();
}

#[test]
fn fail_policy_aborts_with_typed_error_not_hang() {
    let profile =
        FaultProfile { plan: FaultPlan::transient(7, 1.0), policy: RetryPolicy::none() };
    let (machine, ds) = machine_with(profile);
    let engine = train_engine(&machine, &ds, quick_cfg(OnIoError::Fail));
    let err = engine.try_run_epoch(0).expect_err("rate-1.0 faults with no retries must abort");
    let msg = format!("{err:#}");
    assert!(msg.contains("aborted by I/O error"), "unexpected error chain: {msg}");
    assert!(msg.contains("transient"), "the root cause must surface in the chain: {msg}");
    // The abort released every batch's refs on the way out.
    engine.feature_buffer().check_invariants().unwrap();
}

#[test]
fn drop_rows_degrades_gracefully_under_permanent_faults() {
    // Whole device permanently bad: every feature load fails, every batch
    // still trains (on zeroed placeholders) and the epoch completes.
    let profile = FaultProfile {
        plan: FaultPlan { bad_ranges: vec![(0u64, u64::MAX)], ..FaultPlan::default() },
        policy: RetryPolicy::default(),
    };
    let (machine, ds) = machine_with(profile);
    let engine = train_engine(&machine, &ds, quick_cfg(OnIoError::DropRows));
    let stats = engine.try_run_epoch(0).expect("drop-rows must complete under permanent faults");
    assert_eq!(stats.batches, 4);
    assert_eq!(stats.train.steps, 4);
    assert!(stats.dropped_rows > 0, "failed rows must be counted as dropped");
    assert!(stats.io_failures > 0);
    assert_eq!(stats.io_retries, 0, "BadRange is not retryable");
    engine.feature_buffer().check_invariants().unwrap();
}

// ---------------------------------------------------------------------------
// Serving frontend: shed ≠ error ≠ ok under fault storms
// ---------------------------------------------------------------------------

fn serve_cfg(requests: u64) -> ServeConfig {
    ServeConfig {
        tenants: 2,
        workers: 1,
        requests,
        rps: 0.0,
        clients: 2,
        admit_cap: 64,
        batch: BatchSpec { max_requests: 8, max_wait: Duration::from_millis(1) },
        fanouts: vec![4, 4],
        io_depth: 32,
        ..ServeConfig::default()
    }
}

#[test]
fn serve_converts_permanent_faults_into_error_responses() {
    let profile = FaultProfile {
        plan: FaultPlan { bad_ranges: vec![(0u64, u64::MAX)], ..FaultPlan::default() },
        policy: RetryPolicy::default(),
    };
    let (machine, ds) = machine_with(profile);
    let report = ServeEngine::new(&machine, &ds, serve_cfg(40)).unwrap().run(0).unwrap();
    // Closed-loop clients block, so nothing is shed; every admitted request
    // is answered — with a typed error, which still completes the client's
    // call (the run terminating at all is the liveness assertion).
    assert_eq!(report.counts.admitted, 40);
    assert_eq!(report.counts.shed, 0);
    assert_eq!(report.errors, 40, "every request must get a typed error response");
    assert_eq!(report.completed, 0);
}

#[test]
fn serve_rides_out_transient_storm_without_error_responses() {
    let profile = FaultProfile {
        plan: FaultPlan::transient(0x5E6E, 0.10),
        policy: RetryPolicy { max_retries: 8, ..RetryPolicy::default() },
    };
    let (machine, ds) = machine_with(profile);
    let report = ServeEngine::new(&machine, &ds, serve_cfg(40)).unwrap().run(0).unwrap();
    assert_eq!(report.completed, 40, "the retry policy must absorb a 10% storm");
    assert_eq!(report.errors, 0);
    assert!(
        machine.backend.direct_stats().retries.load(Ordering::Relaxed) > 0,
        "the storm must surface as engine retries"
    );
}
